"""Compressed uplinks through the round loop.

Covers the PR-10 tentpole end to end:

* wire-format accounting cross-checked against real serialized buffers,
* the quantize_int8 tuple-pytree regression and exact-k tie semantics,
* EF unbiasedness property tests (hypothesis-fallback compatible),
  including the staleness-weighted carry path (decayed residuals under
  FedBuff-style down-weighting),
* bit-transparency: ``compression=None`` and ``compression="none"``
  reproduce the uncompressed round arrays exactly for every registered
  method,
* billing: compressed uplinks shrink virtual-clock comm time and traffic,
* the joint (rate × level) bandit plumbing, and
* checkpoint/resume with EF residual state.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro import api
from repro.configs import FederatedConfig, TrainConfig, get_config
from repro.core.configurator import JointConfigurator
from repro.data import make_task
from repro.federated import compression as comp
from repro.federated.algorithms import registered_methods

_CFG = get_config("qwen3-1.7b", smoke=True).replace(
    num_layers=4, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
    vocab_size=128, dtype="float32",
)
_FED = FederatedConfig(num_devices=5, devices_per_round=3, local_steps=2, batch_size=8)
_TRAIN = TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2)
_TASK = make_task(num_examples=256, vocab_size=128, seed=0)


def _kw(**extra):
    kw = dict(cfg=_CFG, fed_cfg=_FED, train_cfg=_TRAIN, task=_TASK, seed=0)
    kw.update(extra)
    return kw


# ------------------------------------------------------------- wire format
def _mixed_tree(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(k1, (37, 5)),
        "nested": (jax.random.normal(k2, (64,)), jax.random.normal(k3, (3,))),
        "tiny": jnp.asarray([0.5]),
        "ties": jnp.asarray([1.0, -1.0, 1.0, 0.0, -1.0, 0.5]),
    }


@pytest.mark.parametrize("kind", comp.LEVELS)
def test_compressed_bytes_matches_serialized(key, kind):
    """The accounting and the actual wire buffers can never disagree."""
    tree = _mixed_tree(key)
    cfg = comp.CompressionConfig(kind=kind, topk_fraction=0.25)
    buffers = comp.serialize_compressed(tree, cfg)
    assert comp.compressed_bytes(tree, cfg) == sum(b.nbytes for b in buffers)


def test_no_phantom_scale_bytes():
    """Scale bytes exist only on int8 paths (the old accounting billed
    n_leaves*4 scales even for fp32 top-k payloads)."""
    tree = {"a": jnp.zeros((100,)), "b": jnp.zeros((50,))}
    k_a, k_b = comp.topk_k(100, 0.1), comp.topk_k(50, 0.1)
    assert comp.compressed_bytes(tree, "topk") == 8 * (k_a + k_b)  # no +4/leaf
    assert comp.compressed_bytes(tree, "int8+topk") == 5 * (k_a + k_b) + 2 * 4


def test_per_leaf_k_accounting():
    """k is computed per leaf with the k>=1 floor — a global int(n*f)
    truncation undercounted small leaves to zero entries."""
    tree = {"big": jnp.zeros((100,)), "small": jnp.zeros((3,))}
    # f=0.1: big keeps 10, small keeps the floor of 1 (not 0)
    assert comp.compressed_bytes(tree, "topk") == 8 * (10 + 1)


def test_uplink_ratio_bounds(key):
    tree = _mixed_tree(key)
    assert comp.uplink_ratio(tree, "none") == 1.0
    for kind in ("int8", "topk", "int8+topk"):
        r = comp.uplink_ratio(tree, comp.CompressionConfig(kind=kind, topk_fraction=0.1))
        assert 0.0 < r < 1.0


# -------------------------------------------------------------- quantizer
def test_quantize_int8_tuple_pytree(key):
    """Regression: a pytree with legitimate tuple nodes (the stacked hetlora
    layout) must round-trip with its structure intact — the old tuple-packed
    is_leaf map collapsed it."""
    tree = {
        "layers": (
            {"lora_a": jax.random.normal(key, (4, 8))},
            {"lora_a": jax.random.normal(jax.random.fold_in(key, 1), (4, 8))},
        ),
        "pair": (jnp.ones((3,)), jnp.zeros((2,))),
    }
    vals, scales = comp.quantize_int8(tree)
    assert jax.tree.structure(vals) == jax.tree.structure(tree)
    assert jax.tree.structure(scales) == jax.tree.structure(tree)
    back = comp.dequantize_int8(vals, scales)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.shape == y.shape
        assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_topk_exact_k_on_ties():
    """Four tied magnitudes at the threshold: exactly k survive, lowest flat
    indices win (the old >= threshold kept all four)."""
    x = {"w": jnp.asarray([2.0, -2.0, 2.0, 2.0, 0.1, 0.2, 0.0, 0.3, 0.1, 0.05])}
    sp = comp.topk_sparsify(x, 0.25)  # k = round(2.5) = 3
    nz = np.flatnonzero(np.asarray(sp["w"]))
    assert list(nz) == [0, 1, 2]


@given(n=st.integers(1, 200), f=st.floats(0.01, 1.0))
@settings(max_examples=20, deadline=None)
def test_topk_k_rounds_half_up(n, f):
    k = comp.topk_k(n, f)
    assert 1 <= k
    assert k == max(1, int(np.floor(f * n + 0.5)))


# --------------------------------------------------------- error feedback
@given(kind=st.sampled_from(["int8", "topk", "int8+topk"]), scale=st.floats(0.01, 1.0))
@settings(max_examples=9, deadline=None)
def test_ef_unbiased_over_rounds(kind, scale):
    """Cumulative sent signal tracks the cumulative true signal: the EF
    residual stays bounded, so mean compression error -> 0 over rounds."""
    key = jax.random.PRNGKey(int(scale * 1000) + len(kind))
    true = {"w": scale * jax.random.normal(key, (256,))}
    residual = jax.tree.map(jnp.zeros_like, true)
    sent_sum = jnp.zeros((256,))

    def mean_err_at(rounds, sent_sum, residual, start):
        for _ in range(rounds - start):
            sent, residual = comp.ef_step(true, residual, kind=kind, fraction=0.1)
            sent_sum = sent_sum + sent["w"]
        err = float(jnp.max(jnp.abs(sent_sum / rounds - true["w"])))
        return err, sent_sum, residual

    err15, sent_sum, residual = mean_err_at(15, sent_sum, residual, 0)
    err60, _, _ = mean_err_at(60, sent_sum, residual, 15)
    peak = float(jnp.max(jnp.abs(true["w"])))
    # the per-round bias is residual/rounds: residual stays bounded (by ~one
    # quantization step for int8, ~|x|/fraction for top-k), so it vanishes
    # like 1/rounds — without EF the top-k error would never shrink at all
    assert err60 < max(err15 * 0.55, 1e-5)
    assert err60 < peak * 0.35 + 1e-4


@given(alpha=st.floats(0.25, 2.0))
@settings(max_examples=6, deadline=None)
def test_ef_under_staleness_weighted_carry(alpha):
    """FedBuff-style path: the server down-weights update t by
    w_t = 1/(1+s_t)^alpha while the client decays its residual by the same
    factor (ef_decay) — the decayed-residual correction keeps the *weighted*
    cumulative sent signal tracking the weighted true signal."""
    key = jax.random.PRNGKey(7)
    true = {"w": 0.05 * jax.random.normal(key, (128,))}
    staleness = [0, 1, 2, 0, 3, 1, 0, 2, 1, 0] * 6
    weights = [1.0 / (1.0 + s) ** alpha for s in staleness]

    def run(use_ef):
        residual = jax.tree.map(jnp.zeros_like, true)
        sent_acc, wsum, errs = jnp.zeros((128,)), 0.0, {}
        for t, w in enumerate(weights, 1):
            if use_ef:
                sent, residual = comp.ef_step(
                    true, residual, kind="int8+topk", fraction=0.2
                )
            else:
                sent = comp.compress_decompress(true, kind="int8+topk", fraction=0.2)
            sent_acc = sent_acc + w * sent["w"]
            wsum += w
            errs[t] = float(jnp.max(jnp.abs(sent_acc / wsum - true["w"])))
        return errs

    ef, plain = run(True), run(False)
    # weighted-mean EF error shrinks over rounds despite the staleness
    # discounts breaking the clean telescope...
    assert ef[60] < ef[15] * 0.7 + 1e-6
    # ...while plain compression leaves the unsent coordinates wrong forever
    assert ef[60] < plain[60] * 0.5


def test_ef_decay_shrinks_stale_residual():
    """ef_decay < 1 geometrically forgets old compression error instead of
    replaying it at full weight into a staleness-discounted aggregate."""
    key = jax.random.PRNGKey(3)
    true = {"w": 0.05 * jax.random.normal(key, (128,))}
    res_full = jax.tree.map(jnp.zeros_like, true)
    res_decay = jax.tree.map(jnp.zeros_like, true)
    for _ in range(10):
        _, res_full = comp.ef_step(true, res_full, kind="topk", fraction=0.05, decay=1.0)
        _, res_decay = comp.ef_step(true, res_decay, kind="topk", fraction=0.05, decay=0.5)
    assert float(jnp.sum(jnp.abs(res_decay["w"]))) < float(jnp.sum(jnp.abs(res_full["w"])))


# --------------------------------------------------------- bit transparency
@pytest.mark.parametrize("method", registered_methods())
def test_compression_none_bit_transparent(method):
    """compression="none" (and the filled machinery around it) reproduces
    the pre-compression rounds exactly, for every registered method."""
    base = api.experiment(method, rounds=2, **_kw())
    none = api.experiment(method, rounds=2, compression="none", **_kw())
    for field in ("accuracy", "loss", "cum_time_s", "traffic_mb", "energy_j", "rates"):
        assert np.array_equal(getattr(base, field), getattr(none, field)), (
            method, field,
        )
    assert base.final_accuracy == none.final_accuracy


def test_compression_reduces_comm_billing():
    """int8+topk uplinks shrink billed traffic and the virtual clock."""
    base = api.experiment("droppeft", rounds=2, **_kw())
    cmp_ = api.experiment(
        "droppeft", rounds=2, compression="int8+topk", **_kw()
    )
    assert cmp_.traffic_mb.sum() < base.traffic_mb.sum()
    assert cmp_.cum_time_s[-1] < base.cum_time_s[-1]


def test_compressed_async_runs_with_staleness():
    res = api.experiment(
        "droppeft", rounds=3, compression="int8+topk",
        schedule="async-buffer", staleness_alpha=0.5, **_kw(),
    )
    assert res.rounds == 3
    assert np.all(np.isfinite(res.accuracy))


def test_compression_flag_validation():
    with pytest.raises(ValueError):
        comp.resolve_compression(None, topk_fraction=0.2)
    with pytest.raises(ValueError):
        comp.resolve_compression("int8", topk_fraction=0.0)
    with pytest.raises(ValueError):
        comp.CompressionConfig(kind="int4")


# ------------------------------------------------------------- joint bandit
def test_joint_configurator_arms_and_state():
    j = JointConfigurator(seed=0, levels=comp.LEVELS)
    rates, levels = j.next_round_joint(4)
    assert len(rates) == len(levels) == 4
    assert all(lv in comp.LEVELS for lv in levels)
    arms = list(zip(rates, levels))
    j.report(arms, [0.01] * 4, [10.0] * 4)
    assert all(isinstance(k, tuple) for k in j.arms)
    blob = json.dumps(j.state_dict())
    k = JointConfigurator(seed=99, levels=comp.LEVELS)
    k.load_state_dict(json.loads(blob))
    assert k.arms.keys() == j.arms.keys()
    assert k.next_round_joint(3) == j.next_round_joint(3)


def test_joint_configurator_snaps_float32_rates():
    j = JointConfigurator(seed=0, levels=comp.LEVELS)
    rates, levels = j.next_round_joint(3)
    degraded = [float(np.float32(r)) for r in rates]
    j.report(list(zip(degraded, levels)), [0.01] * 3, [5.0] * 3)
    for rate, _ in j.arms:
        assert rate in {float(r) for r in j.rate_grid} | {0.2, 0.5, 0.7}


def test_joint_configurator_rate_floor():
    j = JointConfigurator(seed=0, levels=comp.LEVELS)
    j.set_rate_floor(0.4)
    rates, levels = j.next_round_joint(6)
    assert all(r >= 0.4 for r in rates)
    assert all(lv in comp.LEVELS for lv in levels)


def test_auto_builds_joint_configurator():
    runner = api.build("droppeft", compression="auto", **_kw())
    assert getattr(runner.state.configurator, "joint", False)
    runner_fixed = api.build("droppeft", compression="int8", **_kw())
    assert not getattr(runner_fixed.state.configurator, "joint", False)


# --------------------------------------------------------- resume + durability
def test_resume_with_compression_bit_exact(tmp_path):
    """EF residuals ride the checkpoint: interrupt-and-resume equals the
    uninterrupted run exactly."""
    ck = str(tmp_path / "ck")
    kw = _kw(compression="int8+topk", checkpoint_dir=ck)
    full = api.experiment("droppeft", rounds=4, **_kw(compression="int8+topk"))
    api.build("droppeft", **kw).run(rounds=2)
    resumed = api.build("droppeft", resume=True, **kw).run(rounds=4)
    assert np.array_equal(full.accuracy, resumed.accuracy)
    assert np.array_equal(full.cum_time_s, resumed.cum_time_s)
    assert full.final_accuracy == resumed.final_accuracy


def test_resume_carry_with_compression(tmp_path):
    """In-flight compressed jobs (uplink reconstruction + level) survive a
    checkpoint/restore under deadline+carry."""
    ck = str(tmp_path / "ck")
    kw = _kw(
        compression="int8", schedule="deadline", deadline_s=5.0,
        straggler="carry", staleness_alpha=0.5, checkpoint_dir=ck,
    )
    full = api.experiment(
        "droppeft", rounds=4,
        **_kw(compression="int8", schedule="deadline", deadline_s=5.0,
              straggler="carry", staleness_alpha=0.5),
    )
    api.build("droppeft", **kw).run(rounds=2)
    resumed = api.build("droppeft", resume=True, **kw).run(rounds=4)
    assert np.array_equal(full.accuracy, resumed.accuracy)
    assert np.array_equal(full.cum_time_s, resumed.cum_time_s)


def test_job_scalar_defaults_tolerate_v2_records():
    """A pre-compression (v2) job record has no "comp"/"has_uplink" keys;
    the scheduler loads it at the defaults instead of KeyError-ing."""
    runner = api.build(
        "droppeft", schedule="deadline", deadline_s=5.0, straggler="carry",
        **_kw(),
    )
    runner.run(rounds=2)
    jobs_arrays, meta = runner.scheduler.state_dict()
    for rec in meta["jobs"]:
        rec.pop("comp", None)
        rec.pop("has_uplink", None)
    for arrs in jobs_arrays:
        arrs.pop("uplink_peft", None)
    runner.scheduler.load_state_dict(jobs_arrays, meta)
    for job in runner.scheduler._jobs.values():
        assert job.comp == ""
        assert job.uplink_peft is None
