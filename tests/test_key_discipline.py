"""PRNG key discipline: the cohort engine's one-split-per-round fan-out must
never hand two devices (or two rounds) the same key path, and both cohort
modes must consume identical streams.  Guards the audit notes in
``repro.core.stld`` and ``repro.data.partition``."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import stld
from repro.federated.engine import CohortEngine


class _RecordingEngine:
    """A stub ``self`` for ``CohortEngine.run_cohort``: records the keys the
    real fan-out code hands to each device instead of training."""

    def __init__(self, cohort_mode, local_steps=2):
        self.cohort_mode = cohort_mode
        self.fed_cfg = type("F", (), {"local_steps": local_steps})()
        self.keys = []
        self.gsteps = []

    def _run_device(self, dev, rate, start_peft, key, gstep, num_classes, adaopt_depth):
        self.keys.append(np.asarray(key))
        self.gsteps.append(gstep)
        return (start_peft, {}, None, 0.0)

    def _run_cohort_batched(
        self, cohort, rates, start_pefts, keys, gsteps, num_classes, adaopt_depth
    ):
        self.keys.extend(np.asarray(k) for k in keys)
        self.gsteps.extend(gsteps)
        return [(p, {}, None, 0.0) for p in start_pefts]


def _run_round(engine, key, global_step=0, n=3):
    return CohortEngine.run_cohort(
        engine, key, global_step, list(range(n)), [0.5] * n, [None] * n, 4, None
    )


def _all_distinct(keys):
    as_tuples = {tuple(np.asarray(k).ravel().tolist()) for k in keys}
    return len(as_tuples) == len(keys)


@pytest.mark.parametrize("mode", ["per-device", "batched"])
def test_cohort_keys_pairwise_distinct(mode):
    eng = _RecordingEngine(mode)
    new_key, _, _ = _run_round(eng, jax.random.PRNGKey(0))
    assert len(eng.keys) == 3
    assert _all_distinct(eng.keys + [np.asarray(new_key)])


@pytest.mark.parametrize("mode", ["per-device", "batched"])
def test_no_key_reuse_across_rounds(mode):
    """The carried key is re-split every round: ten rounds of a 3-device
    cohort must consume 30 pairwise-distinct device keys."""
    eng = _RecordingEngine(mode)
    key = jax.random.PRNGKey(7)
    for r in range(10):
        key, _, _ = _run_round(eng, key, global_step=r * 6)
    assert len(eng.keys) == 30
    assert _all_distinct(eng.keys)


def test_modes_consume_identical_streams():
    """Documented engine invariant: batched and per-device cohorts draw the
    same per-device keys and global-step offsets from the same carry key."""
    a, b = _RecordingEngine("per-device"), _RecordingEngine("batched")
    ka, _, _ = _run_round(a, jax.random.PRNGKey(3))
    kb, _, _ = _run_round(b, jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))
    assert a.gsteps == b.gsteps
    for x, y in zip(a.keys, b.keys):
        np.testing.assert_array_equal(x, y)


def test_gstep_offsets_disjoint_in_cohort_order():
    eng = _RecordingEngine("per-device", local_steps=5)
    _, new_gstep, _ = _run_round(eng, jax.random.PRNGKey(1), global_step=100)
    assert eng.gsteps == [100, 105, 110]
    assert new_gstep == 115


# ------------------------------------------------------- sampler discipline
def test_samplers_consume_key_whole_and_deterministically():
    """stld samplers take the key as-is (no hidden split/fold): same key ->
    identical draw; sibling split keys -> independent draws."""
    rates = jnp.full((8,), 0.5)
    key = jax.random.PRNGKey(42)
    np.testing.assert_array_equal(
        np.asarray(stld.sample_drops(key, rates)),
        np.asarray(stld.sample_drops(key, rates)),
    )
    k1, k2 = jax.random.split(key)
    idx1 = np.asarray(stld.sample_active_indices(k1, rates, 4))
    idx2 = np.asarray(stld.sample_active_indices(k2, rates, 4))
    assert not np.array_equal(idx1, idx2) or not np.array_equal(
        np.asarray(stld.sample_drops(k1, rates)),
        np.asarray(stld.sample_drops(k2, rates)),
    )
