"""Multi-tenant adapter serving: segmented kernel parity, pool hot-swap,
continuous batching, checkpoint round-trip, and stop handling.

The load-bearing claims:

- the segmented gather kernel matches the per-request switching reference
  BIT-FOR-BIT for a mixed-rank (hetlora) pool, including the blocked/padded
  N path — so a multi-tenant server provably changes no tenant's logits;
- a hot-swapped slot's stale high-rank tail is inert (the in-kernel rank
  mask, not a host-side zeroing pass, guarantees it);
- adapter hot-swap in steady state compiles ZERO new XLA programs;
- federated ``save_state`` checkpoints round-trip through the registry to
  identical serving logits.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import save_state
from repro.configs import PEFTConfig, get_config
from repro.core import peft as peft_lib
from repro.kernels.ops import segmented_lora
from repro.kernels.ref import segmented_lora_ref
from repro.launch.steps import make_serve_step
from repro.models.registry import init_params
from repro.serving.adapters import AdapterPoolCache, AdapterRegistry
from repro.serving.batcher import ContinuousBatcher, Request, batched_caches
from repro.serving.decode import generate


def _pool(key, *, m=6, k=32, n=192, ranks=(2, 4, 8)):
    """Random mixed-rank pool: rows cycle through the slots."""
    r_max = max(ranks)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    w = jax.random.normal(ks[1], (k, n), jnp.float32) * 0.1
    a = jax.random.normal(ks[2], (len(ranks), k, r_max), jnp.float32) * 0.1
    b = jax.random.normal(ks[3], (len(ranks), r_max, n), jnp.float32) * 0.1
    # zero each adapter's tail beyond its true rank, as the pool cache does
    for s, r in enumerate(ranks):
        a = a.at[s, :, r:].set(0.0)
        b = b.at[s, r:, :].set(0.0)
    idx = jnp.arange(m, dtype=jnp.int32) % len(ranks)
    return x, w, a, b, idx, jnp.asarray(ranks, jnp.int32)


def test_segmented_kernel_bitexact_mixed_ranks(key):
    x, w, a, b, idx, ranks = _pool(key)  # n=192: exercises block padding
    ref = segmented_lora_ref(x, w, a, b, idx, ranks)
    for block_n in (64, 128):
        out = segmented_lora(x, w, a, b, idx, ranks, block_n=block_n)
        assert np.array_equal(np.asarray(out), np.asarray(ref)), block_n


def test_segmented_kernel_xla_path_allclose(key):
    x, w, a, b, idx, ranks = _pool(key)
    ref = segmented_lora_ref(x, w, a, b, idx, ranks)
    out = segmented_lora(x, w, a, b, idx, ranks, impl="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_hot_swap_stale_tail_inert(key):
    """A rank-4 adapter swapped into a slot that held rank-8 leaves garbage
    in rows/cols 4..8 of the pool; the rank mask must keep it inert."""
    x, w, a, b, idx, _ = _pool(key, ranks=(8, 8, 8))
    ranks = jnp.asarray([4, 8, 8], jnp.int32)  # slot 0 now serves rank 4
    dirty = segmented_lora(x, w, a, b, idx, ranks)
    clean_a = a.at[0, :, 4:].set(0.0)
    clean_b = b.at[0, 4:, :].set(0.0)
    clean = segmented_lora(x, w, clean_a, clean_b, idx, ranks)
    assert np.array_equal(np.asarray(dirty), np.asarray(clean))


def _two_tenant_setup(key, num_layers=2):
    cfg = get_config("qwen3-1.7b", smoke=True).replace(
        num_layers=num_layers, dtype="float32"
    )
    params = init_params(key, cfg)
    trees = {}
    for i, rank in enumerate((4, 8)):
        pcfg = PEFTConfig(method="lora", lora_rank=rank, lora_targets=("q", "v"))
        tree = peft_lib.init_peft(jax.random.fold_in(key, i), cfg, pcfg)
        # randomize b so adapters actually differ (LoRA init keeps b = 0)
        trees[f"client{i}"] = jax.tree.map(
            lambda x: x
            + 0.02 * jax.random.normal(jax.random.fold_in(key, 99), x.shape),
            tree,
        )
    return cfg, params, trees


def test_batched_mixed_adapters_match_per_request_switching(key):
    """Tokens from one mixed-adapter batch == each request served alone
    (whole batch pinned to its adapter) through the same compiled step."""
    cfg, params, trees = _two_tenant_setup(key)
    reg = AdapterRegistry()
    for name, tree in trees.items():
        reg.register(name, tree)
    pool = AdapterPoolCache(reg, n_slots=2)
    serve = make_serve_step(cfg, stack_mode="scan")

    B = 3
    prompts = [[5, 7, 11], [13, 17], [19, 23, 29, 31]]
    adapters = ["client0", "client1", "client0"]

    batcher = ContinuousBatcher(
        serve, params, cfg, pool, batch=B, max_len=16, cache_dtype=jnp.float32
    )
    for j in range(B):
        batcher.submit(
            Request(prompt=prompts[j], adapter=adapters[j], max_new_tokens=4, uid=j)
        )
    done = {c.uid: c for c in batcher.run()}
    assert len(done) == B

    for j in range(B):
        solo = ContinuousBatcher(
            serve, params, cfg, pool, batch=B, max_len=16, cache_dtype=jnp.float32
        )
        # uniform batch: every row is a copy of request j (adapter switching)
        for z in range(B):
            solo.submit(
                Request(
                    prompt=prompts[j],
                    adapter=adapters[j],
                    max_new_tokens=4,
                    uid=f"{j}.{z}",
                )
            )
        ref = {c.uid: c for c in solo.run()}[f"{j}.0"]
        assert done[j].tokens == ref.tokens, j
        assert done[j].finish_reason == ref.finish_reason


@pytest.mark.parametrize("budgets", [(2, 8), (8, 2)])
def test_hot_swap_mid_generation_matches_solo(key, budgets):
    """3 tenants through a 2-slot pool at batch 2: admitting the queued
    third request evicts a slot (hot-swap) while the other row is still
    generating — neither request's tokens may change vs running alone.

    Both budget orders matter: with ``(8, 2)`` the LONG-running row's
    adapter is the LRU-order eviction candidate when t2 admits, so only
    live-row pinning keeps the mid-generation row on its own weights."""
    cfg, params, trees = _two_tenant_setup(key)
    reg = AdapterRegistry()
    for i in range(3):
        reg.register(f"t{i}", trees[f"client{i % 2}"])
    serve = make_serve_step(cfg, stack_mode="scan")

    def serve_all(requests, batch):
        pool = AdapterPoolCache(reg, n_slots=2)
        b = ContinuousBatcher(
            serve, params, cfg, pool, batch=batch, max_len=16,
            cache_dtype=jnp.float32,
        )
        for r in requests:
            b.submit(r)
        return {c.uid: c.tokens for c in b.run()}, pool.swaps

    reqs = [
        Request(prompt=[5, 7], adapter="t0", max_new_tokens=budgets[0], uid=0),
        Request(prompt=[11, 13], adapter="t1", max_new_tokens=budgets[1], uid=1),
        Request(prompt=[17, 19], adapter="t2", max_new_tokens=3, uid=2),
    ]
    got, swaps = serve_all(reqs, batch=2)
    assert swaps == 3  # t2's admission really displaced a resident adapter
    for r in reqs:
        solo, _ = serve_all(
            [Request(prompt=r.prompt, adapter=r.adapter,
                     max_new_tokens=r.max_new_tokens, uid=r.uid)], batch=2
        )
        assert got[r.uid] == solo[r.uid], r.uid


def test_admission_defers_until_slot_free(key):
    """batch > n_slots with all-distinct adapters: the third request must
    wait in the queue (not evict a live row's slot) and admit only after a
    completion releases its pin — tokens still match running alone."""
    cfg, params, trees = _two_tenant_setup(key)
    reg = AdapterRegistry()
    for i in range(3):
        reg.register(f"t{i}", trees[f"client{i % 2}"])
    serve = make_serve_step(cfg, stack_mode="scan")

    def serve_all(requests):
        pool = AdapterPoolCache(reg, n_slots=2)
        b = ContinuousBatcher(
            serve, params, cfg, pool, batch=3, max_len=16,
            cache_dtype=jnp.float32,
        )
        for r in requests:
            b.submit(r)
        return {c.uid: c.tokens for c in b.run()}

    reqs = [
        Request(prompt=[5, 7], adapter="t0", max_new_tokens=6, uid=0),
        Request(prompt=[11, 13], adapter="t1", max_new_tokens=2, uid=1),
        Request(prompt=[17, 19], adapter="t2", max_new_tokens=3, uid=2),
    ]
    got = serve_all(reqs)
    assert len(got) == 3
    for r in reqs:
        solo = serve_all([Request(prompt=r.prompt, adapter=r.adapter,
                                  max_new_tokens=r.max_new_tokens, uid=r.uid)])
        assert got[r.uid] == solo[r.uid], r.uid


def test_batcher_guards(key):
    """submit() rejects prompts that would wrap the KV ring; run() raises
    instead of silently dropping in-flight work on step-budget exhaustion
    or a queue stalled by external pins; lookup() rejects more distinct
    adapters than slots."""
    cfg, params, trees = _two_tenant_setup(key, num_layers=1)
    reg = AdapterRegistry()
    for i in range(3):
        reg.register(f"t{i}", trees[f"client{i % 2}"])
    serve = make_serve_step(cfg, stack_mode="scan")

    def make(pool):
        return ContinuousBatcher(
            serve, params, cfg, pool, batch=2, max_len=8,
            cache_dtype=jnp.float32,
        )

    b = make(AdapterPoolCache(reg, n_slots=2))
    with pytest.raises(ValueError, match="cache positions"):
        b.submit(Request(prompt=list(range(8)), adapter="t0"))

    b.submit(Request(prompt=[3, 5], adapter="t0", max_new_tokens=4, uid=0))
    with pytest.raises(RuntimeError, match="max_steps"):
        b.run(max_steps=1)

    pool = AdapterPoolCache(reg, n_slots=2)
    pool.pin("t0")
    pool.pin("t1")
    b2 = make(pool)
    b2.submit(Request(prompt=[3, 5], adapter="t2", max_new_tokens=2, uid=0))
    with pytest.raises(RuntimeError, match="pinned"):
        b2.run()
    pool.unpin("t0")
    assert len(b2.run()) == 1  # releasing a pin unblocks the queue

    with pytest.raises(ValueError, match="distinct adapters"):
        pool.lookup(["t0", "t1", "t2"])


def test_checkpoint_roundtrip_identical_logits(key, tmp_path):
    """save_state -> load_checkpoint serves logits identical to in-process
    registration of the same trees."""
    cfg, params, trees = _two_tenant_setup(key)

    direct = AdapterRegistry()
    for i, (name, tree) in enumerate(sorted(trees.items())):
        direct.register(f"client{i}", tree)

    state = {
        "device_peft": {str(i): t for i, t in enumerate(
            [t for _, t in sorted(trees.items())]
        )},
    }
    save_state(str(tmp_path), 3, state)
    loaded = AdapterRegistry().load_checkpoint(str(tmp_path))
    assert sorted(loaded.names()) == ["client0", "client1"]

    serve = make_serve_step(cfg, stack_mode="scan")
    B = 2
    token = jnp.asarray([[5], [7]], jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    logits = {}
    for tag, reg in (("direct", direct), ("checkpoint", loaded)):
        pool = AdapterPoolCache(reg, n_slots=2)
        peft = pool.pooled_peft(pool.lookup(["client0", "client1"]))
        caches = batched_caches(cfg, B, 8, dtype=jnp.float32)
        out, _, _ = serve(params, token, pos, caches, peft=peft)
        logits[tag] = np.asarray(out)
    assert np.array_equal(logits["direct"], logits["checkpoint"])


def test_adapter_hot_swap_zero_recompiles(key):
    """Rotating tenants through a full pool (LRU eviction + slot rewrite)
    must not compile a single new XLA program in steady state."""
    from repro.analysis.recompile_guard import recompile_guard

    cfg, params, trees = _two_tenant_setup(key)
    reg = AdapterRegistry()
    for i in range(4):  # 4 tenants, 2 slots -> every rotation hot-swaps
        reg.register(f"t{i}", trees[f"client{i % 2}"])
    pool = AdapterPoolCache(reg, n_slots=2)
    serve = make_serve_step(cfg, stack_mode="scan")
    batcher = ContinuousBatcher(
        serve, params, cfg, pool, batch=2, max_len=16, cache_dtype=jnp.float32
    )

    def round_trip(tenants):
        for j, t in enumerate(tenants):
            batcher.submit(
                Request(prompt=[3 + j, 5], adapter=t, max_new_tokens=3, uid=t)
            )
        return batcher.run()

    round_trip(["t0", "t1"])  # warm: compiles step, slot write, row reset
    swaps_before = pool.swaps
    with recompile_guard(0, label="adapter hot-swap"):
        out = round_trip(["t2", "t3"])
        out += round_trip(["t0", "t1"])
    assert len(out) == 4
    assert pool.swaps - swaps_before == 4  # eviction really happened


def test_pool_lru_eviction_and_pinning(key):
    cfg, params, trees = _two_tenant_setup(key, num_layers=1)
    reg = AdapterRegistry()
    for i in range(3):
        reg.register(f"t{i}", trees[f"client{i % 2}"])
    pool = AdapterPoolCache(reg, n_slots=2)
    s0, s1 = pool.slot_of("t0"), pool.slot_of("t1")
    assert {s0, s1} == {0, 1}
    pool.slot_of("t0")  # refresh t0 -> t1 becomes LRU
    s2 = pool.slot_of("t2")
    assert s2 == s1  # t1 evicted, not t0
    pool.pin("t0")
    pool.pin("t2")
    with pytest.raises(RuntimeError):
        pool.slot_of("t1")  # all slots pinned
    pool.unpin("t2")
    assert pool.slot_of("t1") == s2


def test_generate_eos_and_budget_stops():
    """Per-row stop handling with a deterministic stub step: rows freeze
    independently on EOS or budget; frozen rows emit pad_id."""

    def stub_step(params, token, pos, caches):
        nxt = token + 1
        return None, nxt, caches

    first = jnp.asarray([[5], [10]], jnp.int32)
    toks, _ = generate(
        stub_step, None, jnp.zeros(()), first, 0, 6,
        eos_id=8, max_new_tokens=4,
    )
    assert toks[0].tolist() == [6, 7, 8, 0, 0, 0]  # EOS itself is emitted
    assert toks[1].tolist() == [11, 12, 13, 14, 0, 0]  # budget stop

    # per-row budgets and no-stop path both behave
    toks2, _ = generate(
        stub_step, None, jnp.zeros(()), first, 0, 5,
        max_new_tokens=jnp.asarray([2, 4]),
    )
    assert toks2[0].tolist() == [6, 7, 0, 0, 0]
    assert toks2[1].tolist() == [11, 12, 13, 14, 0]
    toks3, _ = generate(stub_step, None, jnp.zeros(()), first, 0, 3)
    assert toks3[0].tolist() == [6, 7, 8]
