"""Required per-arch smoke tests: a REDUCED variant of each assigned
architecture runs one forward + one train step on CPU; output shapes and
finiteness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, PEFTConfig, TrainConfig, get_config
from repro.core import peft as peft_lib
from repro.launch.steps import make_train_step
from repro.models import init_params, model_apply
from repro.optim import adamw_init


def _batch_for(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.modality == "vision":
        batch["patches"] = 0.1 * jax.random.normal(key, (b, cfg.frontend_seq, cfg.d_model))
    if cfg.modality == "audio":
        batch["frames"] = 0.1 * jax.random.normal(key, (b, cfg.frontend_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch, key):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = init_params(key, cfg)
    batch = _batch_for(cfg, key)
    logits, aux, _ = model_apply(params, cfg, batch)
    expect_s = 16 + (cfg.frontend_seq if cfg.modality == "vision" else 0)
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, key):
    cfg = get_config(arch, smoke=True)
    peft_cfg = PEFTConfig(method="lora", lora_rank=2)
    train_cfg = TrainConfig(learning_rate=1e-3)
    params = init_params(key, cfg)
    peft = peft_lib.init_peft(key, cfg, peft_cfg)
    opt = adamw_init(peft)
    step = make_train_step(cfg, peft_cfg, train_cfg, stld_mode="cond", mean_rate=0.4)
    batch = _batch_for(cfg, key, s=17)  # tokens (B, S+1)
    new_peft, new_opt, metrics = jax.jit(step)(params, peft, opt, batch, key)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # PEFT params moved (at least one leaf changed) unless arch has no targets
    changed = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(peft), jax.tree.leaves(new_peft))
    )
    assert changed
    for leaf in jax.tree.leaves(new_peft):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
