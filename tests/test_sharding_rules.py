"""Sharding rule engine: divisibility fallbacks across the 10 archs.

These tests exercise spec_for_param / cache_specs directly (no devices
needed); the 512-device lowering proof lives in launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import pytest

from repro.configs import ARCH_IDS, PEFTConfig, get_config
from repro.core import peft as peft_lib
from repro.launch.input_specs import eval_param_shapes
from repro.sharding import specs as S

TP = 16


def _specs_for(arch):
    cfg = get_config(arch)
    shapes = eval_param_shapes(cfg)
    return shapes, S.param_specs(shapes, TP)


def _find(tree, *needles):
    found = []

    def visit(path, leaf):
        parts = S._path_parts(path)
        if all(any(n == p for p in parts) for n in needles):
            found.append((parts, leaf))
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return found


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_all_specs_divisible(arch):
    """Every emitted spec must satisfy GSPMD's divisibility requirement."""
    shapes, specs = _specs_for(arch)

    def check(leaf, spec):
        for dim, axes in enumerate(spec):
            if axes is None:
                continue
            assert leaf.shape[dim] % TP == 0, (leaf.shape, spec)

    jax.tree.map(check, shapes, specs, is_leaf=lambda x: isinstance(x, P))


def _drop_layer_lead(parts, spec):
    """Strip the leading stacked-layer None so per-layer rule assertions are
    layout-independent (stacked-native leaves carry a leading (L, ...) axis
    that always replicates)."""
    if S._stacked_layer_lead(parts):
        assert len(spec) == 0 or spec[0] is None
        return P(*tuple(spec)[1:])
    return spec


def test_yi_attention_megatron_pattern():
    shapes, specs = _specs_for("yi-6b")
    wq_parts, wq = _find(specs, "wq", "w")[0]
    wo_parts, wo = _find(specs, "wo", "w")[0]
    assert _drop_layer_lead(wq_parts, wq) == P(None, "model")   # column parallel
    assert _drop_layer_lead(wo_parts, wo) == P("model", None)   # row parallel


def test_llama4_expert_parallel():
    shapes, specs = _specs_for("llama4-scout-17b-a16e")
    parts, eg = _find(specs, "experts", "gate")[0]
    # 16 experts over 16-way axis
    assert _drop_layer_lead(parts, eg) == P("model", None, None)


def test_granite_expert_fallback():
    """40 experts don't divide 16 -> shard within-expert d_ff instead."""
    shapes, specs = _specs_for("granite-moe-3b-a800m")
    parts, eg = _find(specs, "experts", "gate")[0]
    assert _drop_layer_lead(parts, eg) == P(None, None, "model")
    # granite vocab 49155 is not divisible by 16 -> embed shards d_model
    emb = _find(specs, "embed")[0][1]
    assert emb == P(None, "model")


def test_whisper_small_head_fallback():
    """6-head attention cannot TP 16-way on heads, but h*hd=384 divides."""
    shapes, specs = _specs_for("whisper-tiny")
    wq = [(p, x) for p, x in _find(specs, "wq", "w")]
    assert all(_drop_layer_lead(p, s) == P(None, "model") for p, s in wq)


def test_peft_replicated():
    cfg = get_config("yi-6b")
    tree = jax.eval_shape(
        lambda k: peft_lib.init_peft(k, cfg, PEFTConfig(method="lora")),
        jax.random.PRNGKey(0),
    )
    specs = S.peft_specs(tree)
    assert all(s == P() for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))


def test_cache_specs_decode_vs_longcontext():
    cfg = get_config("yi-6b")
    S.set_mesh_axis_sizes(type("M", (), {"shape": {"data": 16, "model": 16}})())
    from repro.models.transformer import init_caches

    caches = jax.eval_shape(lambda: init_caches(cfg, 128, 1024))
    sp = S.cache_specs(caches, ("data",), TP)
    k_spec = sp[0]["k"]
    assert k_spec[0] == "data"          # batch sharded
    assert k_spec[3] == "model"         # kv=4 < 16 -> head_dim sharded

    caches1 = jax.eval_shape(lambda: init_caches(cfg, 1, 4096))
    sp1 = S.cache_specs(caches1, ("data",), TP, shard_seq_on_data=True)
    assert sp1[0]["k"][1] == "data"     # sequence sharded for B=1


def test_rwkv_state_sharding():
    cfg = get_config("rwkv6-3b")
    S.set_mesh_axis_sizes(type("M", (), {"shape": {"data": 16, "model": 16}})())
    from repro.models.transformer import init_caches

    caches = jax.eval_shape(lambda: init_caches(cfg, 128, 16))
    sp = S.cache_specs(caches, ("data",), TP)
    assert sp[0]["shift_tm"][0] == "data"
