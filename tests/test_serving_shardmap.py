"""The sequence-sharded LSE-combined decode under a REAL multi-device
shard_map (8 forced host devices, subprocess so the main test process keeps
its single-device view)."""
import os
import subprocess
import sys

import pytest

_CODE = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp, numpy as np
from repro.serving.decode import sharded_decode_attention, _partial_attention

mesh = jax.make_mesh((8,), ('data',))
key = jax.random.PRNGKey(0)
b, h, d, s = 1, 4, 16, 64
q = jax.random.normal(key, (b, h, d))
k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
kpos = jnp.arange(s)
qpos = 50

out = sharded_decode_attention(mesh, q, k, v, kpos, qpos)
acc, m, l = _partial_attention(q, k, v, kpos, qpos, None)
mono = acc / l[..., None]
err = float(jnp.max(jnp.abs(out - mono.astype(out.dtype))))
assert err < 1e-5, err

# windowed variant
outw = sharded_decode_attention(mesh, q, k, v, kpos, qpos, window=16)
accw, mw, lw = _partial_attention(q, k, v, kpos, qpos, 16)
monow = accw / lw[..., None]
errw = float(jnp.max(jnp.abs(outw - monow.astype(outw.dtype))))
assert errw < 1e-5, errw
print('ok', err, errw)
"""


def test_sharded_decode_attention_8dev():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _CODE], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,
    )
    assert out.returncode == 0 and "ok" in out.stdout, out.stderr[-3000:]
