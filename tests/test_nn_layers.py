"""Unit tests for the NN substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.nn.attention import attention_apply, init_attention, multi_head_attention
from repro.nn.linear import apply_linear, init_linear, init_lora, lora_delta
from repro.nn.mlp import adapter_apply, init_adapter, init_mlp, mlp_apply
from repro.nn.moe import init_moe, moe_apply
from repro.nn.norms import apply_layernorm, apply_rmsnorm, init_layernorm, init_rmsnorm
from repro.nn.rotary import apply_rotary


CFG = get_config("qwen3-1.7b", smoke=True).replace(dtype="float32")


def test_rmsnorm_unit_scale(key):
    p = init_rmsnorm(16)
    x = jax.random.normal(key, (4, 16)) * 10
    y = apply_rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y**2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_layernorm_stats(key):
    p = init_layernorm(32)
    x = jax.random.normal(key, (4, 32)) * 3 + 5
    y = apply_layernorm(p, x)
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-4)
    np.testing.assert_allclose(jnp.std(y, -1), 1.0, atol=1e-2)


def test_rotary_preserves_norm_and_relative(key):
    x = jax.random.normal(key, (1, 8, 2, 16))
    pos = jnp.arange(8)
    y = apply_rotary(x, pos, 10_000.0)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
    )
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.fold_in(key, 1), (16,))
    k = jax.random.normal(jax.random.fold_in(key, 2), (16,))
    def dot_at(p, d):
        rq = apply_rotary(q[None, None, None], jnp.array([p]), 100.0)[0, 0, 0]
        rk = apply_rotary(k[None, None, None], jnp.array([p + d]), 100.0)[0, 0, 0]
        return float(rq @ rk)
    assert abs(dot_at(0, 3) - dot_at(5, 3)) < 1e-3


def test_rotary_disabled():
    x = jnp.ones((1, 4, 1, 8))
    assert (apply_rotary(x, jnp.arange(4), 0.0) == x).all()


def test_lora_zero_init_is_identity(key):
    p = init_linear(key, 8, 12)
    lora = init_lora(jax.random.fold_in(key, 1), 8, 12, 4)
    x = jax.random.normal(key, (3, 8))
    np.testing.assert_allclose(
        apply_linear(p, x), apply_linear(p, x, lora, 2.0), rtol=1e-6
    )
    # after perturbing b, the delta matches scale * x@a@b
    lora2 = dict(lora, b=jnp.ones_like(lora["b"]))
    delta = apply_linear(p, x, lora2, 2.0) - apply_linear(p, x)
    np.testing.assert_allclose(delta, lora_delta(x, lora2, 2.0), rtol=1e-5)


def test_adapter_zero_init_is_identity(key):
    p = init_adapter(key, 16, 4)
    x = jax.random.normal(key, (2, 5, 16))
    np.testing.assert_allclose(adapter_apply(p, x), x, rtol=1e-6)


def test_attention_causality(key):
    cfg = CFG
    p = init_attention(key, cfg)
    x = jax.random.normal(key, (1, 10, cfg.d_model), dtype=jnp.float32)
    out1, _ = attention_apply(p, cfg, x, jnp.arange(10))
    # perturb the future: outputs at earlier positions must not change
    x2 = x.at[:, 7:].add(1.0)
    out2, _ = attention_apply(p, cfg, x2, jnp.arange(10))
    np.testing.assert_allclose(out1[:, :7], out2[:, :7], atol=1e-5)
    assert not np.allclose(out1[:, 7:], out2[:, 7:])


def test_attention_sliding_window_blocks_far_past(key):
    cfg = CFG.replace(sliding_window=4)
    p = init_attention(key, cfg)
    x = jax.random.normal(key, (1, 12, cfg.d_model), dtype=jnp.float32)
    out1, _ = attention_apply(p, cfg, x, jnp.arange(12))
    x2 = x.at[:, 0].add(5.0)  # beyond the window of the last positions
    out2, _ = attention_apply(p, cfg, x2, jnp.arange(12))
    np.testing.assert_allclose(out1[:, 8:], out2[:, 8:], atol=1e-5)


def test_blocked_attention_matches_naive(key):
    import repro.nn.attention as attn_mod

    q = jax.random.normal(key, (2, 300, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 300, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 300, 2, 16))
    pos = jnp.arange(300)
    naive = multi_head_attention(q, k, v, q_positions=pos, k_positions=pos)
    old = attn_mod._MAX_NAIVE_SCORES
    attn_mod._MAX_NAIVE_SCORES = 100 * 100
    try:
        blocked = multi_head_attention(q, k, v, q_positions=pos, k_positions=pos)
    finally:
        attn_mod._MAX_NAIVE_SCORES = old
    np.testing.assert_allclose(naive, blocked, atol=1e-5)


def test_mlp_swiglu_and_gelu(key):
    cfg = CFG
    p = init_mlp(key, cfg)
    x = jax.random.normal(key, (2, 3, cfg.d_model))
    assert mlp_apply(p, cfg, x).shape == x.shape
    cfg_g = cfg.replace(activation="gelu")
    pg = init_mlp(key, cfg_g)
    assert "gate" not in pg and mlp_apply(pg, cfg_g, x).shape == x.shape


def test_moe_aux_loss_and_capacity(key):
    cfg = get_config("granite-moe-3b-a800m", smoke=True).replace(dtype="float32")
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model), dtype=jnp.float32)
    out, aux = moe_apply(p, cfg, x)
    assert out.shape == x.shape
    # The Switch loss E * sum_e f_e * p_e has NO deterministic >=1 bound:
    # f (first-choice token counts) and p (mean softmax probs) only obey the
    # Jensen bound E * sum p_e^2 >= 1 when they coincide, and over a finite
    # token sample the argmax counts can anti-correlate with the mean probs.
    # Principled assertions instead:
    #  (1) a near-uniform random-init router lands within finite-sample
    #      noise of the balanced value 1 (seeded tolerance);
    assert float(aux) == pytest.approx(1.0, abs=0.05)
    #  (2) an exactly-uniform router gives aux == 1 analytically, since
    #      sum_e f_e / E = 1/E for ANY count vector f;
    p_uni = dict(p, router={"w": jnp.zeros_like(p["router"]["w"])})
    _, aux_uni = moe_apply(p_uni, cfg, x)
    assert float(aux_uni) == pytest.approx(1.0, abs=1e-5)
    #  (3) a sharpened router aligns f with p (near one-hot probs), so the
    #      Jensen bound applies and imbalance strictly raises the loss.
    p_sharp = dict(p, router={"w": p["router"]["w"] * 50.0})
    _, aux_sharp = moe_apply(p_sharp, cfg, x)
    assert float(aux_sharp) > 1.0 + 1e-3
    assert float(aux_sharp) > float(aux)


def test_moe_full_capacity_token_conservation(key):
    cfg = get_config("granite-moe-3b-a800m", smoke=True).replace(
        dtype="float32", capacity_factor=8.0
    )
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (1, 16, cfg.d_model), dtype=jnp.float32)
    out, _ = moe_apply(p, cfg, x)
    # with ample capacity every token gets its full top-k combine weight:
    # output must differ from zero everywhere (no dropped tokens)
    assert float(jnp.min(jnp.sum(jnp.abs(out), axis=-1))) > 0.0


def test_shared_expert_path(key):
    cfg = get_config("llama4-scout-17b-a16e", smoke=True).replace(dtype="float32")
    p = init_moe(key, cfg)
    assert "shared" in p
    x = jax.random.normal(key, (2, 4, cfg.d_model), dtype=jnp.float32)
    out, _ = moe_apply(p, cfg, x)
    assert out.shape == x.shape
