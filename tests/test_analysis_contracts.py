"""jaxpr contract engine: estimator correctness, negative fixtures, and the
shipped-algorithm invariant (droppeft passes every contract at smoke scale).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import fixtures, jaxpr_contracts as contracts

_CONTRACT_FIXTURES = sorted(
    r for r in fixtures.FIXTURES if not r.startswith(("JXH", "PYL"))
)


# ---------------------------------------------------------- FLOP estimator
def test_estimate_flops_counts_dot_general():
    """dot_general = 2 · |out| · contraction_size."""
    a = jnp.zeros((8, 16), jnp.float32)
    b = jnp.zeros((16, 4), jnp.float32)
    closed = jax.make_jaxpr(lambda a, b: a @ b)(a, b)
    assert contracts.estimate_flops(closed) == pytest.approx(2 * 8 * 4 * 16)


def test_estimate_flops_multiplies_scan_length():
    """The whole point of the custom estimator: XLA's cost_analysis counts a
    scan body once; ours multiplies by the trip count."""
    w = jnp.zeros((4, 8), jnp.float32)

    def body(h, w_row):
        return h * w_row, None

    def run(h, w):
        h, _ = jax.lax.scan(body, h, w)
        return h

    h = jnp.zeros((8,), jnp.float32)
    one_step = contracts.estimate_flops(jax.make_jaxpr(lambda h, r: h * r)(h, w[0]))
    scanned = contracts.estimate_flops(jax.make_jaxpr(run)(h, w))
    assert scanned == pytest.approx(4 * one_step)


def test_estimate_flops_takes_max_over_cond_branches():
    x = jnp.zeros((8, 8), jnp.float32)

    def f(pred, x):
        return jax.lax.cond(pred, lambda v: v @ v, lambda v: v + 1.0, x)

    closed = jax.make_jaxpr(f)(True, x)
    dot = contracts.estimate_flops(jax.make_jaxpr(lambda v: v @ v)(x))
    assert contracts.estimate_flops(closed) >= dot


def test_linearity_fit():
    slope, resid = contracts._linearity((1.0, 2.0, 4.0), (3.0, 6.0, 12.0))
    assert slope == pytest.approx(3.0) and resid == pytest.approx(0.0)
    slope, resid = contracts._linearity((1.0, 2.0, 4.0), (5.0, 5.0, 5.0))
    assert slope == pytest.approx(0.0)


# ------------------------------------------------------------ walker reuse
def test_walk_eqns_descends_scan_and_accepts_closed_jaxpr():
    w = jnp.zeros((3, 4), jnp.float32)

    def run(h, w):
        h, _ = jax.lax.scan(lambda c, r: (jnp.tanh(c + r), None), h, w)
        return h

    closed = jax.make_jaxpr(run)(jnp.zeros((4,), jnp.float32), w)
    prims = {e.primitive.name for e in contracts.walk_eqns(closed)}
    assert "scan" in prims and "tanh" in prims  # outer eqn AND its body


# --------------------------------------------------------- negative fixtures
@pytest.mark.parametrize("rule_id", _CONTRACT_FIXTURES)
def test_contract_fixture_caught(rule_id):
    found = fixtures.run_fixture(rule_id)
    assert any(v.rule == rule_id for v in found), f"{rule_id} fixture missed"


def test_self_test_all_caught():
    assert all(fixtures.self_test().values())


# ---------------------------------------------------- shipped-code invariant
def test_droppeft_passes_all_contracts():
    """The paper's method passes every contract — structural rules, leaf
    budget, and cost-scaling linearity in the STLD active fraction."""
    violations = contracts.check_algorithms(["droppeft"], include_decode=False)
    assert violations == [], "\n".join(v.render() for v in violations)


def test_client_scaling_curve_is_linear():
    curve = contracts.client_scaling_curve("lora", 2)
    assert contracts.check_curve(curve) == []
    # and strictly increasing in the active fraction
    assert curve.flops[0] < curve.flops[1] < curve.flops[2]
    assert curve.bytes_accessed[0] < curve.bytes_accessed[2]


@pytest.mark.slow
def test_full_registry_passes_all_contracts():
    violations = contracts.check_algorithms()
    assert violations == [], "\n".join(v.render() for v in violations)
