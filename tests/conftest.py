import jax
import pytest

# Smoke tests and benches must see the single real CPU device (the 512-device
# override lives ONLY inside launch/dryrun.py, which runs as its own process).


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
