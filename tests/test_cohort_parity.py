"""Batched cohort engine == sequential per-device loop, and the
configurator's vector-rate interface.

The batched engine (``cohort_round`` = vmap of ``local_round``) must be a
pure execution-strategy change: for identical seeds both modes consume the
same PRNG streams and must produce numerically matching per-device PEFT
trees, round metrics, PTLS importances, and accuracies.  Exercised through
the new ``repro.api`` / ``ExperimentRunner`` surface.
"""
import jax
import numpy as np
import pytest

from repro import api
from repro.configs import FederatedConfig, PEFTConfig, STLDConfig, TrainConfig, get_config
from repro.core.configurator import OnlineConfigurator

_CFG = get_config("qwen3-1.7b", smoke=True).replace(
    num_layers=4, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
    vocab_size=128, dtype="float32",
)
_FED = FederatedConfig(num_devices=6, devices_per_round=4, local_steps=2, batch_size=8)
_TRAIN = TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2)


def _runner(mode, *, method="droppeft", stld_mode="cond", seed=3):
    return api.build(
        method,
        cfg=_CFG,
        peft_cfg=PEFTConfig(method="lora", lora_rank=2),
        stld_cfg=STLDConfig(mode=stld_mode, mean_rate=0.5, gather_bucket=1),
        fed_cfg=_FED,
        train_cfg=_TRAIN,
        seed=seed,
        cohort_mode=mode,
    )


def _tree_allclose(a, b, atol=1e-5):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(
            np.asarray(x, dtype=np.float64), np.asarray(y, dtype=np.float64), atol=atol
        )


def _run_cohort(runner, cohort, rates):
    state = runner.state
    start = [state.global_peft for _ in cohort]
    _, _, outs = runner.ctx.engine.run_cohort(
        state.key, 0, cohort, rates, start, runner.ctx.num_classes,
        runner.ctx.cfg.num_layers,
    )
    return outs


@pytest.mark.parametrize("stld_mode", ["cond", "gather"])
def test_cohort_round_parity(stld_mode):
    """Per-device PEFT trees, metrics, importances, and accuracies match
    between batched and sequential execution for the same PRNG keys.  The
    gather case exercises the static-count cohort grouping (two groups)."""
    run_s = _runner("sequential", stld_mode=stld_mode)
    run_b = _runner("batched", stld_mode=stld_mode)
    cohort = [0, 1, 2, 3]
    rates = [0.25, 0.5, 0.25, 0.5]

    outs_s = _run_cohort(run_s, cohort, rates)
    outs_b = _run_cohort(run_b, cohort, rates)
    assert len(outs_s) == len(outs_b) == 4
    for (p_s, m_s, imp_s, acc_s), (p_b, m_b, imp_b, acc_b) in zip(outs_s, outs_b):
        _tree_allclose(p_s, p_b)
        np.testing.assert_allclose(
            np.asarray(imp_s), np.asarray(imp_b), atol=1e-4, rtol=1e-4
        )
        for k in ("loss", "accuracy", "active_layers"):
            assert float(m_s[k]) == pytest.approx(float(m_b[k]), abs=1e-4)
        assert acc_s == pytest.approx(acc_b, abs=1e-5)


def test_full_run_parity_smoke():
    """End-to-end: both modes trace identical accuracy/loss/cost curves."""
    res_s = _runner("sequential").run(rounds=3)
    res_b = _runner("batched").run(rounds=3)
    np.testing.assert_allclose(res_s.accuracy, res_b.accuracy, atol=1e-5)
    np.testing.assert_allclose(res_s.loss, res_b.loss, atol=1e-4)
    np.testing.assert_allclose(res_s.cum_time_s, res_b.cum_time_s, rtol=1e-6)
    np.testing.assert_allclose(res_s.active_fraction, res_b.active_fraction, atol=1e-5)
    np.testing.assert_allclose(res_s.traffic_mb, res_b.traffic_mb, rtol=1e-6)
    assert res_s.final_accuracy == pytest.approx(res_b.final_accuracy, abs=1e-5)


def test_hetlora_forces_sequential_fallback():
    runner = _runner("auto", method="fedhetlora")
    assert runner.cohort_mode == "sequential"
    with pytest.raises(ValueError):
        _runner("batched", method="fedhetlora")


def test_configurator_vector_rate_interface():
    """Regression: per-device rate vectors (float32 arrays, as produced by
    the batched engine) round-trip through next_round/report without minting
    duplicate float32-drifted arms."""
    cfgor = OnlineConfigurator(
        rate_grid=(0.1, 0.3, 0.5),
        startup=(0.1, 0.5),
        num_candidates=2,
        explore_rate=0.5,
        explore_interval=2,
        seed=0,
    )
    for _ in range(8):
        rates = cfgor.next_round(4, as_array=True)
        assert isinstance(rates, np.ndarray) and rates.dtype == np.float32
        gains = np.full(4, 0.1, dtype=np.float32)
        times = np.ones(4, dtype=np.float32)
        cfgor.report(rates, gains, times)
    grid = (0.1, 0.3, 0.5)
    for arm_rate in cfgor.arms:
        assert any(arm_rate == g for g in grid), f"drifted arm key {arm_rate!r}"
    assert cfgor.best_rate() in grid
