"""Batched cohort engine == sequential per-device loop, and the
configurator's vector-rate interface.

The batched engine (``cohort_round`` = vmap of ``local_round``) must be a
pure execution-strategy change: for identical seeds both modes consume the
same PRNG streams and must produce numerically matching per-device PEFT
trees, round metrics, PTLS importances, and accuracies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FederatedConfig, PEFTConfig, STLDConfig, TrainConfig, get_config
from repro.core.configurator import OnlineConfigurator
from repro.federated.simulator import FederatedSimulator

_CFG = get_config("qwen3-1.7b", smoke=True).replace(
    num_layers=4, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
    vocab_size=128, dtype="float32",
)
_FED = FederatedConfig(num_devices=6, devices_per_round=4, local_steps=2, batch_size=8)
_TRAIN = TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2)


def _sim(mode, *, strategy="droppeft", stld_mode="cond", seed=3):
    return FederatedSimulator(
        _CFG,
        PEFTConfig(method="lora", lora_rank=2),
        STLDConfig(mode=stld_mode, mean_rate=0.5, gather_bucket=1),
        _FED,
        _TRAIN,
        strategy=strategy,
        seed=seed,
        cohort_mode=mode,
    )


def _tree_allclose(a, b, atol=1e-5):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(
            np.asarray(x, dtype=np.float64), np.asarray(y, dtype=np.float64), atol=atol
        )


@pytest.mark.parametrize("stld_mode", ["cond", "gather"])
def test_cohort_round_parity(stld_mode):
    """Per-device PEFT trees, metrics, importances, and accuracies match
    between batched and sequential execution for the same PRNG keys.  The
    gather case exercises the static-count cohort grouping (two groups)."""
    sim_s = _sim("sequential", stld_mode=stld_mode)
    sim_b = _sim("batched", stld_mode=stld_mode)
    cohort = [0, 1, 2, 3]
    rates = [0.25, 0.5, 0.25, 0.5]
    num_classes = jnp.arange(sim_s.task.num_classes)

    outs_s = sim_s._run_cohort(cohort, rates, num_classes, _CFG.num_layers)
    outs_b = sim_b._run_cohort(cohort, rates, num_classes, _CFG.num_layers)
    assert len(outs_s) == len(outs_b) == 4
    for (p_s, m_s, imp_s, acc_s), (p_b, m_b, imp_b, acc_b) in zip(outs_s, outs_b):
        _tree_allclose(p_s, p_b)
        np.testing.assert_allclose(
            np.asarray(imp_s), np.asarray(imp_b), atol=1e-4, rtol=1e-4
        )
        for k in ("loss", "accuracy", "active_layers"):
            assert float(m_s[k]) == pytest.approx(float(m_b[k]), abs=1e-4)
        assert acc_s == pytest.approx(acc_b, abs=1e-5)


def test_full_run_parity_smoke():
    """End-to-end: both modes trace identical accuracy/loss/cost curves."""
    res_s = _sim("sequential").run(rounds=3)
    res_b = _sim("batched").run(rounds=3)
    np.testing.assert_allclose(res_s.accuracy, res_b.accuracy, atol=1e-5)
    np.testing.assert_allclose(res_s.loss, res_b.loss, atol=1e-4)
    np.testing.assert_allclose(res_s.cum_time_s, res_b.cum_time_s, rtol=1e-6)
    np.testing.assert_allclose(res_s.active_fraction, res_b.active_fraction, atol=1e-5)
    np.testing.assert_allclose(res_s.traffic_mb, res_b.traffic_mb, rtol=1e-6)
    assert res_s.final_accuracy == pytest.approx(res_b.final_accuracy, abs=1e-5)


def test_hetlora_forces_sequential_fallback():
    sim = _sim("auto", strategy="fedhetlora")
    assert sim.cohort_mode == "sequential"
    with pytest.raises(ValueError):
        _sim("batched", strategy="fedhetlora")


def test_configurator_vector_rate_interface():
    """Regression: per-device rate vectors (float32 arrays, as produced by
    the batched engine) round-trip through next_round/report without minting
    duplicate float32-drifted arms."""
    cfgor = OnlineConfigurator(
        rate_grid=(0.1, 0.3, 0.5),
        startup=(0.1, 0.5),
        num_candidates=2,
        explore_rate=0.5,
        explore_interval=2,
        seed=0,
    )
    for _ in range(8):
        rates = cfgor.next_round(4, as_array=True)
        assert isinstance(rates, np.ndarray) and rates.dtype == np.float32
        gains = np.full(4, 0.1, dtype=np.float32)
        times = np.ones(4, dtype=np.float32)
        cfgor.report(rates, gains, times)
    grid = (0.1, 0.3, 0.5)
    for arm_rate in cfgor.arms:
        assert any(arm_rate == g for g in grid), f"drifted arm key {arm_rate!r}"
    assert cfgor.best_rate() in grid
