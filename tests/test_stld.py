"""STLD core: sampling statistics, gating semantics, schedules, gather mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.core import stld
from repro.core.schedules import drop_rates, unit_shape
from repro.models import init_params, model_apply


def test_expected_active_layers():
    rates = jnp.array([0.0, 0.5, 1.0, 0.25])
    assert float(stld.expected_active_layers(rates)) == pytest.approx(2.25)


def test_sample_drops_statistics(key):
    rates = jnp.array([0.1, 0.5, 0.9] * 4)
    keys = jax.random.split(key, 2000)
    drops = jax.vmap(lambda k: stld.sample_drops(k, rates, 1))(keys)
    freq = np.asarray(jnp.mean(drops.astype(jnp.float32), axis=0))
    np.testing.assert_allclose(freq, np.asarray(rates), atol=0.05)


def test_sample_drops_min_active(key):
    rates = jnp.full((6,), 0.95)
    keys = jax.random.split(key, 500)
    drops = jax.vmap(lambda k: stld.sample_drops(k, rates, 2))(keys)
    active = np.asarray(jnp.sum(~drops, axis=1))
    assert active.min() >= 2


def test_sample_active_indices_sorted_unique(key):
    rates = unit_shape("incremental", 12) * 0.5
    idx = stld.sample_active_indices(key, jnp.clip(rates, 0, 0.95), 5)
    idx = np.asarray(idx)
    assert len(np.unique(idx)) == 5
    assert (np.sort(idx) == idx).all()


@given(mean=st.floats(0.05, 0.9), L=st.integers(2, 64))
@settings(max_examples=30, deadline=None)
def test_drop_rates_mean_property(mean, L):
    for dist in ("uniform", "incremental", "decay"):
        r = np.asarray(drop_rates(dist, mean, L))
        assert (r >= 0).all() and (r <= 0.95).all()
        # mean preserved when no clipping occurred
        if r.max() < 0.95 - 1e-6:
            assert abs(r.mean() - mean) < 1e-4


def test_incremental_monotone_decay_antitone():
    inc = np.asarray(drop_rates("incremental", 0.4, 10))
    dec = np.asarray(drop_rates("decay", 0.4, 10))
    assert (np.diff(inc) >= -1e-7).all()
    assert (np.diff(dec) <= 1e-7).all()


def test_static_active_count():
    assert stld.static_active_count(0.5, 24, bucket=4) == 12
    assert stld.static_active_count(0.9, 24, bucket=4) == 4
    assert stld.static_active_count(0.99, 24, bucket=1, min_active=2) == 2
    assert stld.static_active_count(0.0, 24) == 24


def test_gate_skip_is_identity(key):
    h = jax.random.normal(key, (2, 3, 8))
    cache = {"x": jnp.ones((2, 2))}
    block = lambda hh, cc: (hh * 2.0, jnp.ones(()), jax.tree.map(lambda t: t + 1, cc))
    h1, aux1, c1 = stld.gate(block, jnp.array(True), h, cache)
    np.testing.assert_allclose(h1, h)
    assert float(aux1) == 0.0
    np.testing.assert_allclose(c1["x"], cache["x"])
    h2, aux2, c2 = stld.gate(block, jnp.array(False), h, cache)
    np.testing.assert_allclose(h2, h * 2.0)
    assert float(aux2) == 1.0


def test_all_dropped_reduces_to_head_only(key):
    cfg = get_config("yi-6b", smoke=True).replace(num_layers=3, dtype="float32")
    params = init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    drops = jnp.ones((3,), dtype=bool)
    logits, _, _ = model_apply(params, cfg, batch, drops=drops)
    # equals embed -> final_norm -> head with no layers
    cfg0 = cfg.replace(num_layers=0)
    params0 = dict(params, layers=[])
    logits0, _, _ = model_apply(params0, cfg0, batch)
    np.testing.assert_allclose(logits, logits0, atol=1e-5)


def test_gather_equals_cond_for_same_active_set(key):
    cfg = get_config("glm4-9b", smoke=True).replace(num_layers=4, dtype="float32")
    params = init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    active = jnp.array([0, 2])
    drops = jnp.array([False, True, False, True])
    lg, _, _ = model_apply(params, cfg, batch, stack_mode="gather", active_idx=active)
    lc, _, _ = model_apply(params, cfg, batch, drops=drops)
    np.testing.assert_allclose(lg, lc, atol=1e-5)


def test_gather_grads_zero_for_dropped_layers(key):
    from repro.configs import PEFTConfig
    from repro.core import peft as peft_lib

    cfg = get_config("yi-6b", smoke=True).replace(num_layers=4, dtype="float32")
    params = init_params(key, cfg)
    peft = peft_lib.init_peft(key, cfg, PEFTConfig(method="lora", lora_rank=2))
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    active = jnp.array([1, 3])

    def loss(pf):
        lo, _, _ = model_apply(
            params, cfg, batch, peft=pf, stack_mode="gather", active_idx=active
        )
        return jnp.mean(lo**2)

    from repro.models import stacking

    g = jax.grad(loss)(peft)
    for l in (0, 2):  # dropped layers get exactly zero grads
        g_l = jax.tree.leaves(stacking.layer_view(g, l))
        assert all(float(jnp.abs(x).max()) == 0.0 for x in g_l)
    for l in (1, 3):
        g_l = jax.tree.leaves(stacking.layer_view(g, l))
        assert any(float(jnp.abs(x).max()) > 0.0 for x in g_l)
