"""Serving correctness: KV-cache / recurrent-state decode must reproduce the
full-sequence forward, per architecture family; ring-buffer SWA; sharded
long-context decode math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.models.transformer import init_caches, lm_apply
from repro.serving.decode import _partial_attention


def _decode_all(cfg, params, toks, max_len, prefill_len=0):
    caches = init_caches(cfg, toks.shape[0], max_len, dtype=jnp.float32)
    outs = []
    start = 0
    if prefill_len:
        lp, _, caches = lm_apply(params, cfg, toks[:, :prefill_len], caches=caches)
        outs.extend([lp[:, i] for i in range(prefill_len)])
        start = prefill_len
    for t in range(start, toks.shape[1]):
        lt, _, caches = lm_apply(
            params, cfg, toks[:, t : t + 1], positions=jnp.array([t]), caches=caches
        )
        outs.append(lt[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize(
    "arch,kw",
    [
        ("yi-6b", {}),
        ("rwkv6-3b", {}),
        ("jamba-v0.1-52b", {"capacity_factor": 8.0}),
        ("qwen3-1.7b", {}),
    ],
)
def test_decode_matches_full_forward(arch, kw, key):
    cfg = get_config(arch, smoke=True).replace(num_layers=2, dtype="float32", **kw)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    full, _, _ = lm_apply(params, cfg, toks)
    dec = _decode_all(cfg, params, toks, max_len=32, prefill_len=6)
    np.testing.assert_allclose(dec, full, atol=2e-4)


def test_swa_ring_buffer_cache(key):
    """Ring-buffer decode (cache shorter than sequence) == full forward."""
    cfg = get_config("h2o-danube-1.8b", smoke=True).replace(
        num_layers=2, dtype="float32", sliding_window=8
    )
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (1, 20), 0, cfg.vocab_size)
    full, _, _ = lm_apply(params, cfg, toks)
    # cache of window size (8) << seq (20): wraps multiple times
    caches = init_caches(cfg, 1, 8, dtype=jnp.float32)
    outs = []
    for t in range(20):
        lt, _, caches = lm_apply(
            params, cfg, toks[:, t : t + 1], positions=jnp.array([t]), caches=caches
        )
        outs.append(lt[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full, atol=2e-4)


def test_whisper_decode_matches_full(key):
    from repro.models import encdec

    cfg = get_config("whisper-tiny", smoke=True).replace(dtype="float32")
    params = init_params(key, cfg)
    frames = 0.1 * jax.random.normal(key, (2, cfg.frontend_seq, cfg.d_model))
    toks = jax.random.randint(key, (2, 10), 0, cfg.vocab_size)
    enc_out = encdec.encode(params, cfg, frames)
    enc_kvs = encdec.encoder_cross_kvs(params, cfg, enc_out)
    full, _, _ = encdec.decode(params, cfg, toks, enc_kvs)
    caches = encdec.init_decoder_caches(cfg, 2, 32, dtype=jnp.float32)
    outs = []
    for t in range(10):
        lt, _, caches = encdec.decode(
            params, cfg, toks[:, t : t + 1], enc_kvs,
            positions=jnp.array([t]), caches=caches,
        )
        outs.append(lt[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full, atol=2e-4)


def test_partial_attention_lse_combine(key):
    """Splitting the KV cache into shards and LSE-combining partials must
    equal monolithic attention (the long_500k decode path math)."""
    b, h, d, s = 1, 4, 16, 64
    q = jax.random.normal(key, (b, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    kpos = jnp.arange(s)
    qpos = 40

    # monolithic
    acc, m, l = _partial_attention(q, k, v, kpos, qpos, None)
    mono = acc / l[..., None]

    # two shards + LSE combine
    halves = [(k[:, :32], v[:, :32], kpos[:32]), (k[:, 32:], v[:, 32:], kpos[32:])]
    parts = [_partial_attention(q, kk, vv, pp, qpos, None) for kk, vv, pp in halves]
    m_glob = jnp.maximum(parts[0][1], parts[1][1])
    l_glob = sum(p[2] * jnp.exp(p[1] - m_glob) for p in parts)
    acc_glob = sum(p[0] * jnp.exp(p[1] - m_glob)[..., None] for p in parts)
    combined = acc_glob / l_glob[..., None]
    np.testing.assert_allclose(combined, mono, atol=1e-5)


def test_generate_greedy_consistency(key):
    """generate() must equal hand-rolled greedy decode."""
    from repro.launch.steps import make_prefill_step, make_serve_step
    from repro.serving.decode import generate

    cfg = get_config("yi-6b", smoke=True).replace(num_layers=2, dtype="float32")
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    prefill = make_prefill_step(cfg)
    serve = make_serve_step(cfg)
    caches = init_caches(cfg, 2, 24, dtype=jnp.float32)
    last, caches = prefill(params, {"tokens": toks}, caches)
    first = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
    gen, _ = generate(serve, params, caches, first, 8, 4)

    # manual loop
    caches2 = init_caches(cfg, 2, 24, dtype=jnp.float32)
    last2, caches2 = prefill(params, {"tokens": toks}, caches2)
    tok = jnp.argmax(last2, axis=-1)[:, None].astype(jnp.int32)
    manual = []
    for i in range(4):
        _, tok_next, caches2 = serve(params, tok, jnp.asarray(8 + i), caches2)
        manual.append(tok_next[:, 0])
        tok = tok_next
    np.testing.assert_array_equal(np.asarray(gen), np.stack(manual, axis=1))
