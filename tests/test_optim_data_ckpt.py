"""Optimizer math, synthetic data/partitioning, checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, restore_latest, save_pytree
from repro.data import DeviceDataset, dirichlet_partition, make_task
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, make_lr_schedule


def test_adamw_first_step_math():
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.5, -0.5])}
    state = adamw_init(params)
    new, state = adamw_update(grads, state, params, lr=0.1, weight_decay=0.0)
    # bias-corrected first step == -lr * sign-ish: m/(sqrt(v)+eps) = g/|g|
    np.testing.assert_allclose(new["w"], [0.9, 2.1], atol=1e-4)


def test_adamw_weight_decay_pulls_to_zero():
    params = {"w": jnp.array([10.0])}
    grads = {"w": jnp.array([0.0])}
    state = adamw_init(params)
    new, _ = adamw_update(grads, state, params, lr=0.1, weight_decay=0.1)
    assert float(new["w"][0]) < 10.0


def test_clip_by_global_norm():
    grads = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(5.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_shapes():
    sched = make_lr_schedule("cosine", 1.0, 10, 100)
    assert float(sched(0)) < 0.2
    assert float(sched(10)) == pytest.approx(1.0, abs=0.05)
    assert float(sched(99)) < 0.01


def test_synthetic_task_signal():
    task = make_task(num_examples=512, vocab_size=256, seq_len=24, num_classes=4, seed=1)
    # class signature tokens must be informative: count tokens in class range
    half, width = 128, 32
    hits = 0
    for i in range(100):
        c = task.labels[i]
        lo = half + c * width
        hits += ((task.tokens[i] >= lo) & (task.tokens[i] < lo + width)).sum() > 4
    assert hits > 80
    b = task.lm_batch(np.arange(8))
    assert b["mask"].sum() == 8  # loss only at the final position
    assert (b["targets"][:, -1] == 1 + b["labels"]).all()


def test_dirichlet_partition_noniid():
    task = make_task(num_examples=2000, num_classes=4, seed=0)
    parts_iid = dirichlet_partition(task.labels, 10, alpha=100.0, seed=0)
    parts_skew = dirichlet_partition(task.labels, 10, alpha=0.1, seed=0)
    assert sum(len(p) for p in parts_iid) >= 1900

    def skew(parts):
        # mean max-class-share across devices
        shares = []
        for p in parts:
            lab = task.labels[p]
            shares.append(max(np.bincount(lab, minlength=4)) / max(len(lab), 1))
        return np.mean(shares)

    assert skew(parts_skew) > skew(parts_iid) + 0.15


def test_device_dataset_batching():
    task = make_task(num_examples=256, seed=0)
    ds = DeviceDataset(task, np.arange(40), seed=0)
    batches = list(ds.train_batches(16, 3))
    assert len(batches) == 3
    assert all(b["tokens"].shape == (16, task.seq_len) for b in batches)
    assert ds.val_batch()["tokens"].shape[0] >= 1


def test_checkpoint_roundtrip(tmp_path, key):
    tree = {
        "layers": [{"w": jax.random.normal(key, (3, 4))}, {"w": jnp.ones((2,), jnp.bfloat16)}],
        "step": jnp.array(7),
    }
    d = save_pytree(tree, str(tmp_path), 7)
    restored = load_pytree(tree, d)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
    tree2, step = restore_latest(tree, str(tmp_path))
    assert step == 7
