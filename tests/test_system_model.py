"""SystemModel golden values + monotonicity (paper §3.2 / §6.1, Table 2).

The golden tests recompute one tx2 and one agx ``RoundCost`` and a
``MemoryBreakdown`` by hand — explicit arithmetic from the paper's formulas
on a config small enough to audit — so a regression in any accounting term
(FLOPs/token, activation bytes, comm bytes, energy split) fails with a
number, not a vibe.  The property tests pin the STLD contract the
scheduler's deadline policy relies on: cost strictly decreasing in the
dropout fraction rho, and the paper-scale memory footprint fitting each
Jetson tier at its chosen ratio.
"""
import numpy as np
import pytest

from repro.configs import ModelConfig, PEFTConfig, get_config
from repro.federated.system_model import (
    DEVICE_PROFILES,
    SystemModel,
    sample_bandwidth,
)

# small, fully-auditable dense config
_CFG = ModelConfig(
    name="golden", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=1000,
    activation="silu", tie_embeddings=False,
)
_PEFT = PEFTConfig(method="lora", lora_rank=4, lora_targets=("q", "v"))

# ---- hand-derived constants for _CFG (see param_counts) -------------------
# head_dim = 64/4 = 16
# attn  = d*(h*hd) + 2*d*(kv*hd) + (h*hd)*d = 4096 + 4096 + 4096 = 12288
# mlp   = 3*d*ff = 24576 ;  norms = 2*d = 128
# layer = 36992 ; 2 layers = 73984
# emb   = vocab*d = 64000 ; total += emb + d + emb (untied) = 128064
_TOTAL = 202_048
_EMB = 64_000
_LAYER_PARAMS = _TOTAL - _EMB          # active == total for a dense model
# LoRA rank 4 on (q, v): q -> r*(d + h*hd) = 512 ; v -> r*(d + kv*hd) = 384
_PEFT_PARAMS = (512 + 384) * 2         # 1792


def test_peft_param_count_hand_computed():
    sm = SystemModel(_CFG, _PEFT)
    assert sm.peft_params == _PEFT_PARAMS
    assert sm.total_params == _TOTAL
    assert sm.active_params == _TOTAL


def _expected_round_cost(profile, *, batch, seq, local_steps, bw, af, sf):
    """Independent arithmetic: the paper's accounting, written out."""
    prof = DEVICE_PROFILES[profile]
    tokens = batch * seq * local_steps
    fwd = 2 * (_LAYER_PARAMS * af + _EMB)
    bwd = fwd + 6 * _PEFT_PARAMS * af           # PEFT backward (frozen base)
    compute_time = tokens * (fwd + bwd) / prof.flops
    comm_bytes = _PEFT_PARAMS * sf * 4 + _PEFT_PARAMS * 4   # fp32 up + down
    comm_time = comm_bytes * 8 / (bw * 1e6)
    energy = prof.compute_watts * compute_time + prof.radio_watts * comm_time
    traffic_mb = comm_bytes / 1024.0**2
    gb = 1024.0**3
    act_per_tok = (20 * 64 + 4 * 128) * 2 * 2 * af + 2 * 64 * 2  # 2 layers + final norm
    memory = (
        _TOTAL * 2 / gb                          # bf16 params
        + act_per_tok * batch * seq / gb         # stored activations
        + _PEFT_PARAMS * af * 4 / gb             # fp32 grads
        + _PEFT_PARAMS * af * 8 / gb             # fp32 AdamW m+v
    )
    return compute_time, comm_time, memory, energy, traffic_mb


@pytest.mark.parametrize("profile,af,sf", [("tx2", 1.0, 1.0), ("agx", 0.5, 0.25)])
def test_round_cost_golden(profile, af, sf):
    sm = SystemModel(_CFG, _PEFT)
    got = sm.round_cost(
        device=profile, bandwidth_mbps=40.0, batch=2, seq=16, local_steps=2,
        peft=True, active_fraction=af, share_fraction=sf,
    )
    ct, mt, mem, en, tr = _expected_round_cost(
        profile, batch=2, seq=16, local_steps=2, bw=40.0, af=af, sf=sf
    )
    assert got.compute_time_s == pytest.approx(ct, rel=1e-12)
    assert got.comm_time_s == pytest.approx(mt, rel=1e-12)
    assert got.memory_gb == pytest.approx(mem, rel=1e-12)
    assert got.energy_j == pytest.approx(en, rel=1e-12)
    assert got.traffic_mb == pytest.approx(tr, rel=1e-12)
    assert got.total_time_s == pytest.approx(ct + mt, rel=1e-12)


def test_memory_breakdown_golden_tx2_config():
    """Field-by-field MemoryBreakdown audit at tx2-style settings."""
    sm = SystemModel(_CFG, _PEFT)
    mb = sm.memory_breakdown(batch=2, seq=16, peft=True, active_fraction=0.5)
    gb = 1024.0**3
    assert mb.params_gb == pytest.approx(_TOTAL * 2 / gb, rel=1e-12)
    assert mb.activations_gb == pytest.approx(
        ((20 * 64 + 4 * 128) * 2 * 2 * 0.5 + 256) * 32 / gb, rel=1e-12
    )
    assert mb.gradients_gb == pytest.approx(_PEFT_PARAMS * 0.5 * 4 / gb, rel=1e-12)
    assert mb.optimizer_gb == pytest.approx(_PEFT_PARAMS * 0.5 * 8 / gb, rel=1e-12)
    assert mb.total_gb == pytest.approx(
        mb.params_gb + mb.activations_gb + mb.gradients_gb + mb.optimizer_gb
    )


def test_cost_strictly_decreasing_in_dropout_fraction():
    """More dropout -> strictly less compute time, energy and memory at the
    paper scale, for every device tier (comm is rho-independent; PTLS's
    share fraction handles that axis)."""
    sm = SystemModel(get_config("qwen3-1.7b"), PEFTConfig(method="lora"))
    rhos = np.linspace(0.0, 0.9, 10)
    for profile in DEVICE_PROFILES:
        costs = [
            sm.round_cost(
                device=profile, bandwidth_mbps=40.0, batch=16, seq=128,
                local_steps=4, peft=True, active_fraction=1.0 - rho,
                share_fraction=1.0,
            )
            for rho in rhos
        ]
        compute = np.array([c.compute_time_s for c in costs])
        total = np.array([c.total_time_s for c in costs])
        energy = np.array([c.energy_j for c in costs])
        memory = np.array([c.memory_gb for c in costs])
        assert (np.diff(compute) < 0).all(), profile
        assert (np.diff(total) < 0).all(), profile
        assert (np.diff(energy) < 0).all(), profile
        assert (np.diff(memory) < 0).all(), profile
        comm = np.array([c.comm_time_s for c in costs])
        np.testing.assert_allclose(comm, comm[0])


def test_paper_ratios_fit_device_memory_caps():
    """At the paper's chosen dropout ratios the 1.7B PEFT footprint fits
    each Jetson tier's RAM (Table 2): tx2 8GB needs aggressive dropout,
    agx 32GB fits even the full depth."""
    sm = SystemModel(get_config("qwen3-1.7b"), PEFTConfig(method="lora"))
    chosen = {"tx2": 0.8, "nx": 0.5, "agx": 0.0}   # rho per tier
    for profile, rho in chosen.items():
        mb = sm.memory_breakdown(
            batch=16, seq=128, peft=True, active_fraction=1.0 - rho
        )
        cap = DEVICE_PROFILES[profile].memory_gb
        assert mb.total_gb < cap, (
            f"{profile}: {mb.total_gb:.2f}GB exceeds the {cap}GB cap at rho={rho}"
        )
    # and the converse sanity: tx2 cannot hold the full-depth footprint
    full = sm.memory_breakdown(batch=16, seq=128, peft=True, active_fraction=1.0)
    assert full.total_gb > DEVICE_PROFILES["tx2"].memory_gb


def test_bandwidth_sampler_bounds():
    rng = np.random.default_rng(0)
    draws = np.array([sample_bandwidth(rng) for _ in range(1000)])
    assert draws.min() >= 1.0 and draws.max() <= 100.0
