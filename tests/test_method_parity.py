"""The hook-based ExperimentRunner reproduces the pre-refactor
``FederatedSimulator.run()`` bit-for-bit, for every registered method.

The baseline is ``tests/_legacy_simulator.py`` — a frozen verbatim copy of
the god-class as it stood before the api_redesign PR.  Parity is exact
(``np.array_equal``, no tolerances): the redesign is a pure restructuring,
so every SimResult array and the final all-device accuracy must be
identical for identical seeds.  The deprecation shim, which delegates to
the runner, must match too.
"""
import warnings

import numpy as np
import pytest

from _legacy_simulator import FederatedSimulator as LegacySimulator
from repro import api
from repro.configs import FederatedConfig, PEFTConfig, STLDConfig, TrainConfig, get_config
from repro.data import make_task

_CFG = get_config("qwen3-1.7b", smoke=True).replace(
    num_layers=4, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
    vocab_size=128, dtype="float32",
)
_FED = FederatedConfig(num_devices=5, devices_per_round=3, local_steps=2, batch_size=8)
_TRAIN = TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2)
_ROUNDS = 3
_FIELDS = (
    "cum_time_s", "accuracy", "loss", "rates",
    "active_fraction", "traffic_mb", "energy_j", "memory_gb",
)


def _task():
    # SyntheticTask is stateless (plain arrays), so one instance is shared
    return make_task(num_examples=256, vocab_size=128, seed=0)


_TASK = _task()


def _peft_cfg(method):
    kind = "adapter" if method in ("fedadapter", "fedadaopt") else "lora"
    return PEFTConfig(method=kind, lora_rank=2, adapter_dim=4)


def _stld_cfg(mode="cond"):
    return STLDConfig(mode=mode, mean_rate=0.5, gather_bucket=1)


def _assert_results_equal(res_old, res_new):
    assert res_old.rounds == res_new.rounds
    for f in _FIELDS:
        np.testing.assert_array_equal(
            getattr(res_old, f), getattr(res_new, f), err_msg=f
        )
    assert res_old.final_accuracy == res_new.final_accuracy


# droppeft (full method, batched) and fedhetlora (sequential + rank
# heterogeneity) cover both execution paths in the fast tier; the remaining
# methods ride in the slow tier
_FAST = ("droppeft", "fedhetlora")


@pytest.mark.parametrize(
    "method",
    [
        m if m in _FAST else pytest.param(m, marks=pytest.mark.slow)
        for m in api.list_methods()
    ],
)
def test_runner_reproduces_legacy_bit_for_bit(method):
    peft_cfg, stld_cfg = _peft_cfg(method), _stld_cfg()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = LegacySimulator(
            _CFG, peft_cfg, stld_cfg, _FED, _TRAIN,
            strategy=method, seed=3, task=_TASK,
        )
    res_old = legacy.run(rounds=_ROUNDS)
    res_new = api.experiment(
        method, cfg=_CFG, peft_cfg=peft_cfg, stld_cfg=stld_cfg,
        fed_cfg=_FED, train_cfg=_TRAIN, seed=3, task=_TASK, rounds=_ROUNDS,
    )
    _assert_results_equal(res_old, res_new)


@pytest.mark.slow
def test_runner_reproduces_legacy_gather_mode():
    """Gather-mode STLD exercises the static-count cohort partitioning."""
    peft_cfg, stld_cfg = _peft_cfg("droppeft"), _stld_cfg("gather")
    legacy = LegacySimulator(
        _CFG, peft_cfg, stld_cfg, _FED, _TRAIN,
        strategy="droppeft", seed=5, task=_TASK,
    )
    res_old = legacy.run(rounds=_ROUNDS)
    res_new = api.experiment(
        "droppeft", cfg=_CFG, peft_cfg=peft_cfg, stld_cfg=stld_cfg,
        fed_cfg=_FED, train_cfg=_TRAIN, seed=5, task=_TASK, rounds=_ROUNDS,
    )
    _assert_results_equal(res_old, res_new)


def test_shim_warns_and_delegates_identically():
    """The retained FederatedSimulator surface is a pure delegation shim:
    it must emit a DeprecationWarning and produce the same results as the
    repro.api path."""
    from repro.federated.simulator import FederatedSimulator

    peft_cfg, stld_cfg = _peft_cfg("droppeft"), _stld_cfg()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        sim = FederatedSimulator(
            _CFG, peft_cfg, stld_cfg, _FED, _TRAIN,
            strategy="droppeft", seed=3, task=_TASK,
        )
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    res_shim = sim.run(rounds=_ROUNDS)
    res_api = api.experiment(
        "droppeft", cfg=_CFG, peft_cfg=peft_cfg, stld_cfg=stld_cfg,
        fed_cfg=_FED, train_cfg=_TRAIN, seed=3, task=_TASK, rounds=_ROUNDS,
    )
    _assert_results_equal(res_shim, res_api)
    # the legacy attribute surface still works
    assert sim.cohort_mode == "batched"
    assert sim.global_peft is sim.runner.state.global_peft
