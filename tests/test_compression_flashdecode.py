"""Tests for the uplink compressors, flash-decode kernel, block-STLD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.federated.compression import (
    ErrorFeedback,
    compressed_bytes,
    dequantize_int8,
    int8_roundtrip,
    quantize_int8,
    topk_sparsify,
)
from repro.kernels.flash_decode import flash_decode_pallas
from repro.serving.decode import _partial_attention


# --------------------------------------------------------------- compression
def test_int8_roundtrip_error(key):
    tree = {"a": 0.1 * jax.random.normal(key, (64, 8)), "b": jnp.linspace(-2, 2, 32)}
    v, s = quantize_int8(tree)
    back = dequantize_int8(v, s)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        rel = float(jnp.sqrt(jnp.mean((x - y) ** 2)) / (jnp.std(x) + 1e-9))
        assert rel < 0.01
    assert all(x.dtype == jnp.int8 for x in jax.tree.leaves(v))


def test_compressed_bytes_ratio(key):
    tree = {"w": jnp.zeros((1000,))}
    full = 1000 * 4
    assert compressed_bytes(tree, "none") == full
    assert compressed_bytes(tree, "int8") == 1000 + 4
    assert compressed_bytes(tree, "topk") == 8 * 100     # k=100 at f=0.1
    assert compressed_bytes(tree, "int8+topk") == 5 * 100 + 4


def test_topk_sparsify(key):
    x = {"w": jax.random.normal(key, (100,))}
    sp = topk_sparsify(x, 0.1)
    nz = int(jnp.sum(sp["w"] != 0))
    assert nz == 10  # exact-k: ties can no longer inflate the kept set
    kept = jnp.abs(sp["w"])[sp["w"] != 0]
    dropped_max = jnp.max(jnp.abs(jnp.where(sp["w"] == 0, x["w"], 0)))
    assert float(jnp.min(kept)) >= float(dropped_max) - 1e-6


def test_error_feedback_unbiased_over_rounds(key):
    """With EF, the cumulative transmitted signal converges to the cumulative
    true signal (residual stays bounded)."""
    true = {"w": 0.01 * jax.random.normal(key, (256,))}
    residual = ErrorFeedback.init(true)
    sent_sum = jnp.zeros((256,))
    for i in range(20):
        sent, residual = ErrorFeedback.compress(true, residual, int8_roundtrip)
        sent_sum = sent_sum + sent["w"]
    total_err = float(jnp.max(jnp.abs(sent_sum - 20 * true["w"])))
    # residual bounded by one quantization step
    assert total_err < float(jnp.max(jnp.abs(true["w"]))) * 0.2 + 1e-4


# --------------------------------------------------------------- flash decode
@pytest.mark.parametrize(
    "b,h,kv,d,s,qpos,window,bk",
    [
        (2, 4, 2, 32, 100, 80, None, 32),
        (1, 8, 8, 64, 64, 63, None, 64),
        (1, 4, 4, 32, 96, 90, 24, 32),   # sliding window
    ],
)
def test_flash_decode_vs_partial_attention(key, b, h, kv, d, s, qpos, window, bk):
    q = jax.random.normal(key, (b, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, d))
    kpos = jnp.arange(s)
    out = flash_decode_pallas(q, k, v, kpos, qpos, window=window, block_k=bk)

    rep = h // kv
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    acc, m, l = _partial_attention(q, kk, vv, kpos, qpos, window)
    expect = acc / l[..., None]
    np.testing.assert_allclose(out, expect.astype(out.dtype), atol=3e-5)


def test_flash_decode_ring_positions(key):
    """Wrapped ring-buffer slot positions mask correctly."""
    b, h, d, s = 1, 2, 16, 32
    q = jax.random.normal(key, (b, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    # ring holding absolute positions 40..71 permuted modulo 32
    kpos = 40 + jnp.mod(jnp.arange(s) - 40, s)
    out = flash_decode_pallas(q, k, v, kpos, 71, block_k=16)
    acc, m, l = _partial_attention(q, k, v, kpos, 71, None)
    np.testing.assert_allclose(out, (acc / l[..., None]).astype(out.dtype), atol=3e-5)


# ------------------------------------------------------------------ block stld
@given(bs=st.sampled_from([2, 4]), mean=st.floats(0.2, 0.8))
@settings(max_examples=10, deadline=None)
def test_block_stld_structure(bs, mean):
    from repro.core.stld import sample_drops_block

    key = jax.random.PRNGKey(int(mean * 100) + bs)
    rates = jnp.full((12,), mean)
    drops = sample_drops_block(key, rates, bs, min_active=1)
    d = np.asarray(drops)
    assert (~d).sum() >= 1
    # within each full block, gates agree except where min-active forcing hit
    forced = (~d).sum() == 1 and d.sum() == 11
    if not forced:
        for i in range(0, 12 - bs + 1, bs):
            blk = d[i : i + bs]
            assert blk.all() or (~blk).any()
