"""Equivalence tests for the beyond-paper performance variants
(EXPERIMENTS.md §Perf): every optimized path must match its baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PEFTConfig, TrainConfig, get_config
from repro.models import init_params, model_apply
from repro.nn.moe import init_moe, moe_apply


@pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "llama4-scout-17b-a16e"])
def test_moe_gather_dispatch_equals_einsum(arch, key):
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 24, cfg.d_model), dtype=jnp.float32)
    oe, ae = moe_apply(p, cfg, x, dispatch_mode="einsum")
    og, ag = moe_apply(p, cfg, x, dispatch_mode="gather")
    np.testing.assert_allclose(oe, og, atol=1e-5)
    np.testing.assert_allclose(ae, ag, atol=1e-6)


def test_moe_weight_gather_equals_full_capacity(key):
    cfg = get_config("llama4-scout-17b-a16e", smoke=True).replace(
        dtype="float32", capacity_factor=8.0
    )
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 2, cfg.d_model))  # t=4 -> weight-gather path
    og, _ = moe_apply(p, cfg, x)
    oe, _ = moe_apply(p, cfg, x, dispatch_mode="einsum_forced")
    np.testing.assert_allclose(og, oe, atol=1e-5)


def test_moe_weight_gather_grads_flow(key):
    cfg = get_config("granite-moe-3b-a800m", smoke=True).replace(dtype="float32")
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (1, 4, cfg.d_model))

    def loss(p):
        out, _ = moe_apply(p, cfg, x)
        return jnp.mean(out**2)

    g = jax.grad(loss)(p)
    assert any(float(jnp.abs(l).max()) > 0 for l in jax.tree.leaves(g))


def test_gather_unroll_equals_gather_scan(key):
    cfg = get_config("qwen3-1.7b", smoke=True).replace(num_layers=4, dtype="float32")
    params = init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    idx = jnp.array([0, 3])
    ls, _, _ = model_apply(params, cfg, batch, stack_mode="gather", active_idx=idx)
    lu, _, _ = model_apply(params, cfg, batch, stack_mode="gather_unroll", active_idx=idx)
    np.testing.assert_allclose(ls, lu, atol=1e-5)


def test_train_step_gather_unroll_mode(key):
    from repro.core import peft as peft_lib
    from repro.launch.steps import make_train_step
    from repro.optim import adamw_init

    cfg = get_config("yi-6b", smoke=True).replace(num_layers=4, dtype="float32")
    pcfg = PEFTConfig(method="lora", lora_rank=2)
    params = init_params(key, cfg)
    peft = peft_lib.init_peft(key, cfg, pcfg)
    step = make_train_step(
        cfg, pcfg, TrainConfig(), stld_mode="gather", mean_rate=0.5, stack_mode="unroll"
    )
    batch = {"tokens": jax.random.randint(key, (2, 9), 0, cfg.vocab_size)}
    new_peft, _, metrics = jax.jit(step)(params, peft, adamw_init(peft), batch, key)
    assert bool(jnp.isfinite(metrics["loss"]))


def test_fsdp_specs_divisible():
    from repro.launch.input_specs import eval_param_shapes
    from repro.sharding import specs as S
    from jax.sharding import PartitionSpec as P

    S.set_mesh_axis_sizes(type("M", (), {"shape": {"data": 16, "model": 16}})())
    cfg = get_config("internvl2-76b")
    shapes = eval_param_shapes(cfg)
    specs = S.param_specs(shapes, 16, fsdp_axes=("data",))

    fsdp_bytes = 0
    big_bytes = 0

    def check(leaf, spec):
        nonlocal fsdp_bytes, big_bytes
        has_fsdp = False
        for dim, axes in enumerate(spec):
            if axes is None:
                continue
            names = axes if isinstance(axes, tuple) else (axes,)
            size = 1
            for nm in names:
                size *= 16
            assert leaf.shape[dim] % size == 0
            if "data" in names:
                has_fsdp = True
        size = int(np.prod(leaf.shape))
        if size >= 1 << 20:  # the param_specs big-leaf threshold (elements)
            nbytes = size * leaf.dtype.itemsize
            big_bytes += nbytes
            if has_fsdp:
                fsdp_bytes += nbytes

    jax.tree.map(check, shapes, specs, is_leaf=lambda x: isinstance(x, P))
    # most big-weight bytes got an fsdp dim (layout-independent: the stacked
    # layer layout has 16x fewer but 16x larger leaves than the list layout)
    assert big_bytes > 0 and fsdp_bytes / big_bytes > 0.8, (fsdp_bytes, big_bytes)

    # the stacked layer axis must never be sharded: lax.scan iterates it, so
    # a data-axis sharding there would reshard the operand every layer
    def check_layer_axis(path, spec):
        parts = S._path_parts(path)
        if S._stacked_layer_lead(parts) and len(spec):
            assert spec[0] is None, (parts, spec)
        return spec

    jax.tree_util.tree_map_with_path(
        check_layer_axis, specs, is_leaf=lambda x: isinstance(x, P)
    )
