"""PTLS (Eq. 6, Fig. 8) and the bandit configurator (Algorithm 1).

The property tests (via ``_hypothesis_fallback``: real hypothesis when the
wheel is present, a seeded parametrize shim offline) pin the configurator
invariants the virtual-clock scheduler leans on: float32 round-trips never
mint duplicate arms, window eviction never deletes the current best arm,
``next_round(as_array=True)`` entries always lie on ``rate_grid``, and
rewards stay finite as round times approach zero.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro.core import ptls
from repro.core.configurator import OnlineConfigurator


def test_importance_accumulator_eq6():
    acc = ptls.ImportanceAccumulator.init(3)
    # batch 1: layer 0,2 active with norms [1,9,5]; layer 1 dropped
    acc = ptls.ImportanceAccumulator.update(acc, jnp.array([1.0, 9.0, 5.0]), jnp.array([0.0, 1.0, 0.0]))
    # batch 2: all active, norms [3, 2, 1]
    acc = ptls.ImportanceAccumulator.update(acc, jnp.array([3.0, 2.0, 1.0]), jnp.zeros(3))
    imp = np.asarray(ptls.ImportanceAccumulator.importance(acc))
    np.testing.assert_allclose(imp, [2.0, 2.0, 3.0])


def test_shared_layer_mask_lowest_importance():
    imp = jnp.array([5.0, 1.0, 3.0, 0.5])
    mask = np.asarray(ptls.shared_layer_mask(imp, 2))
    assert mask.tolist() == [False, True, False, True]


def test_masked_layer_mean_overlap_and_keep():
    # 3 devices, 2 layers; layer 1 shared by devices 0,2; layer 0 by nobody
    prev = [{"w": jnp.zeros((2,))}, {"w": jnp.full((2,), -1.0)}]
    updates = [
        jax.tree.map(lambda *xs: jnp.stack(xs), *[{"w": jnp.full((2,), float(i))} for i in range(3)])
        for _ in range(2)
    ]
    masks = jnp.array([[False, True], [False, False], [False, True]])
    out = ptls.masked_layer_mean(updates, masks, prev)
    np.testing.assert_allclose(out[0]["w"], prev[0]["w"])  # nobody shared -> keep
    np.testing.assert_allclose(out[1]["w"], jnp.full((2,), 1.0))  # mean(0, 2)


def test_layer_grad_norms():
    grads = [{"a": jnp.array([3.0, 4.0])}, {}, {"b": jnp.array([1.0]), "c": jnp.array([2.0, 2.0])}]
    norms = np.asarray(ptls.layer_grad_norms(grads))
    np.testing.assert_allclose(norms, [5.0, 0.0, 3.0])


def test_configurator_converges_to_best_arm():
    cfgor = OnlineConfigurator(
        rate_grid=(0.1, 0.5, 0.9), startup=(0.1, 0.5, 0.9),
        num_candidates=3, explore_rate=0.34, explore_interval=3, seed=0,
    )
    # ground truth: reward peaks at 0.5
    def reward(r):
        return 1.0 - (r - 0.5) ** 2 + 0.01 * np.random.default_rng(int(r * 10)).standard_normal()

    picks = []
    for _ in range(30):
        rates = cfgor.next_round(4)
        gains = [reward(r) for r in rates]
        times = [1.0] * 4
        cfgor.report(rates, gains, times)
        picks.extend(rates)
    assert cfgor.best_rate() == pytest.approx(0.5)
    # exploitation phases should make 0.5 the most-used arm
    assert max(set(picks), key=picks.count) == 0.5


def test_configurator_phase_alternation():
    cfgor = OnlineConfigurator(startup=(0.2, 0.6), num_candidates=2, explore_rate=0.5, explore_interval=2)
    phases = []
    for _ in range(10):
        phases.append(cfgor.is_explore)
        rates = cfgor.next_round(2)
        cfgor.report(rates, [0.1] * 2, [1.0] * 2)
    assert True in phases and False in phases


# --------------------------------------------------------------------------
# property tests (Algorithm-1 invariants the scheduler relies on)
# --------------------------------------------------------------------------

_GRIDS = (
    (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9),
    (0.1, 0.25, 0.4, 0.55, 0.7),
    (0.05, 0.5, 0.95),
)


@settings(max_examples=12, deadline=None)
@given(
    grid_idx=st.integers(min_value=0, max_value=2),
    n_devices=st.integers(min_value=1, max_value=6),
    rounds=st.integers(min_value=1, max_value=25),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_float32_roundtrip_never_mints_duplicate_arms(grid_idx, n_devices, rounds, seed):
    """Feeding ``next_round(as_array=True)``'s float32 vector straight back
    into ``report`` must snap onto the exact arm keys: the arm table never
    grows a near-duplicate key and never leaves the grid."""
    grid = _GRIDS[grid_idx]
    cfgor = OnlineConfigurator(
        rate_grid=grid, startup=grid[:2], num_candidates=3,
        explore_rate=0.34, explore_interval=2, window_size=4, seed=seed,
    )
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        rates = cfgor.next_round(n_devices, as_array=True)
        assert rates.dtype == np.float32
        cfgor.report(
            rates,
            rng.uniform(0.0, 1.0, n_devices).astype(np.float32),
            rng.uniform(0.5, 2.0, n_devices).astype(np.float32),
        )
        keys = sorted(cfgor.arms)
        assert len(keys) <= len(grid)
        for a, b in zip(keys, keys[1:]):
            assert b - a > 1e-5, f"float32 round-trip minted duplicate arms {a}, {b}"
        for k in keys:
            assert min(abs(k - g) for g in grid) < 1e-6, f"off-grid arm {k!r}"


@settings(max_examples=8, deadline=None)
@given(
    window=st.integers(min_value=2, max_value=6),
    seed=st.integers(min_value=0, max_value=999),
)
def test_window_eviction_never_deletes_best_arm(window, seed):
    """An arm that won big long ago must survive the staleness eviction
    while other arms are evaluated for many windows: exploitation must
    always be able to return to the known best."""
    grid = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)
    cfgor = OnlineConfigurator(
        rate_grid=grid, startup=(0.9,), num_candidates=2,
        explore_rate=0.5, explore_interval=1, window_size=window, seed=seed,
    )
    cfgor.next_round(1)
    cfgor.report([0.9], [100.0], [1.0])       # overwhelming early winner
    losers = [r for r in grid if r != 0.9]
    for i in range(window * 4):
        cfgor.next_round(1)
        cfgor.report([losers[i % len(losers)]], [0.001], [1.0])
        assert 0.9 in cfgor.arms, "window eviction deleted the best arm"
        assert cfgor.best_rate() == 0.9


@settings(max_examples=10, deadline=None)
@given(
    grid_idx=st.integers(min_value=0, max_value=2),
    n_devices=st.integers(min_value=1, max_value=8),
    rounds=st.integers(min_value=1, max_value=20),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_next_round_array_entries_lie_on_rate_grid(grid_idx, n_devices, rounds, seed):
    grid = _GRIDS[grid_idx]
    cfgor = OnlineConfigurator(
        rate_grid=grid, startup=grid[-2:], num_candidates=3,
        explore_rate=0.34, explore_interval=3, window_size=5, seed=seed,
    )
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        rates = cfgor.next_round(n_devices, as_array=True)
        assert rates.shape == (n_devices,)
        for r in rates:
            assert min(abs(float(r) - g) for g in grid) < 1e-6, (
                f"rate {r!r} not on grid {grid}"
            )
        cfgor.report(rates, rng.uniform(0.0, 1.0, n_devices), rng.uniform(0.5, 2.0, n_devices))


@settings(max_examples=10, deadline=None)
@given(
    t=st.floats(min_value=0.0, max_value=1e-12),
    gain=st.floats(min_value=0.0, max_value=1.0),
)
def test_zero_round_times_keep_rewards_finite(t, gain):
    """The max(t, 1e-9) guard: a virtual round that closes instantly (e.g.
    an async buffer of already-finished arrivals) must not mint inf/nan
    rewards."""
    cfgor = OnlineConfigurator()
    rates = cfgor.next_round(2)
    cfgor.report(rates, [gain] * 2, [t] * 2)
    for arm in cfgor.arms.values():
        assert np.isfinite(arm.reward)
    assert np.isfinite(cfgor.best_rate())


def test_rate_floor_caps_candidates():
    """Deadline-aware mode: once a floor is set, every subsequent rate the
    configurator hands out is feasible (>= floor) and still on the grid."""
    grid = (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)
    cfgor = OnlineConfigurator(rate_grid=grid, startup=(0.2, 0.5, 0.7), seed=0)
    rng = np.random.default_rng(0)
    for _ in range(3):  # accumulate some low-rate evidence first
        rates = cfgor.next_round(4)
        cfgor.report(rates, rng.uniform(0, 1, 4), [1.0] * 4)
    cfgor.set_rate_floor(0.4)
    for _ in range(12):
        rates = cfgor.next_round(4)
        assert all(r >= 0.4 for r in rates), rates
        assert all(any(abs(r - g) < 1e-6 for g in grid) for r in rates)
        cfgor.report(rates, rng.uniform(0, 1, 4), [1.0] * 4)
    assert cfgor.best_rate() >= 0.4
    # floor round-trips through the checkpoint snapshot
    blob = cfgor.state_dict()
    fresh = OnlineConfigurator(rate_grid=grid)
    fresh.load_state_dict(blob)
    assert fresh.rate_floor == 0.4
