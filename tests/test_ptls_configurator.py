"""PTLS (Eq. 6, Fig. 8) and the bandit configurator (Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ptls
from repro.core.configurator import OnlineConfigurator


def test_importance_accumulator_eq6():
    acc = ptls.ImportanceAccumulator.init(3)
    # batch 1: layer 0,2 active with norms [1,9,5]; layer 1 dropped
    acc = ptls.ImportanceAccumulator.update(acc, jnp.array([1.0, 9.0, 5.0]), jnp.array([0.0, 1.0, 0.0]))
    # batch 2: all active, norms [3, 2, 1]
    acc = ptls.ImportanceAccumulator.update(acc, jnp.array([3.0, 2.0, 1.0]), jnp.zeros(3))
    imp = np.asarray(ptls.ImportanceAccumulator.importance(acc))
    np.testing.assert_allclose(imp, [2.0, 2.0, 3.0])


def test_shared_layer_mask_lowest_importance():
    imp = jnp.array([5.0, 1.0, 3.0, 0.5])
    mask = np.asarray(ptls.shared_layer_mask(imp, 2))
    assert mask.tolist() == [False, True, False, True]


def test_masked_layer_mean_overlap_and_keep():
    # 3 devices, 2 layers; layer 1 shared by devices 0,2; layer 0 by nobody
    prev = [{"w": jnp.zeros((2,))}, {"w": jnp.full((2,), -1.0)}]
    updates = [
        jax.tree.map(lambda *xs: jnp.stack(xs), *[{"w": jnp.full((2,), float(i))} for i in range(3)])
        for _ in range(2)
    ]
    masks = jnp.array([[False, True], [False, False], [False, True]])
    out = ptls.masked_layer_mean(updates, masks, prev)
    np.testing.assert_allclose(out[0]["w"], prev[0]["w"])  # nobody shared -> keep
    np.testing.assert_allclose(out[1]["w"], jnp.full((2,), 1.0))  # mean(0, 2)


def test_layer_grad_norms():
    grads = [{"a": jnp.array([3.0, 4.0])}, {}, {"b": jnp.array([1.0]), "c": jnp.array([2.0, 2.0])}]
    norms = np.asarray(ptls.layer_grad_norms(grads))
    np.testing.assert_allclose(norms, [5.0, 0.0, 3.0])


def test_configurator_converges_to_best_arm():
    cfgor = OnlineConfigurator(
        rate_grid=(0.1, 0.5, 0.9), startup=(0.1, 0.5, 0.9),
        num_candidates=3, explore_rate=0.34, explore_interval=3, seed=0,
    )
    # ground truth: reward peaks at 0.5
    def reward(r):
        return 1.0 - (r - 0.5) ** 2 + 0.01 * np.random.default_rng(int(r * 10)).standard_normal()

    picks = []
    for _ in range(30):
        rates = cfgor.next_round(4)
        gains = [reward(r) for r in rates]
        times = [1.0] * 4
        cfgor.report(rates, gains, times)
        picks.extend(rates)
    assert cfgor.best_rate() == pytest.approx(0.5)
    # exploitation phases should make 0.5 the most-used arm
    assert max(set(picks), key=picks.count) == 0.5


def test_configurator_phase_alternation():
    cfgor = OnlineConfigurator(startup=(0.2, 0.6), num_candidates=2, explore_rate=0.5, explore_interval=2)
    phases = []
    for _ in range(10):
        phases.append(cfgor.is_explore)
        rates = cfgor.next_round(2)
        cfgor.report(rates, [0.1] * 2, [1.0] * 2)
    assert True in phases and False in phases
