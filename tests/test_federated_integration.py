"""Federated integration: the full DropPEFT loop + baselines on a tiny model.

These are the paper-claim validation tests at smoke scale:
  * training improves accuracy over rounds (loss down, acc > chance),
  * STLD reduces per-round compute/memory in the system model,
  * PTLS aggregation preserves personalization,
  * baselines (FedAdapter, FedHetLoRA) run end-to-end.
"""
import numpy as np
import pytest

from repro import api
from repro.configs import FederatedConfig, PEFTConfig, STLDConfig, TrainConfig, get_config

_CFG = get_config("qwen3-1.7b", smoke=True).replace(
    num_layers=4, d_model=64, d_ff=128, num_heads=4, num_kv_heads=2,
    vocab_size=512, dtype="float32",
)
_FED = FederatedConfig(num_devices=8, devices_per_round=4, local_steps=4, batch_size=16)
_TRAIN = TrainConfig(learning_rate=5e-3, total_steps=400, warmup_steps=5)


def _run(strategy, rounds=8, stld_mode="cond", peft="lora", seed=0):
    return api.experiment(
        strategy,
        cfg=_CFG,
        peft_cfg=PEFTConfig(method=peft, lora_rank=4, adapter_dim=8),
        stld_cfg=STLDConfig(mode=stld_mode, mean_rate=0.5, gather_bucket=1),
        fed_cfg=_FED,
        train_cfg=_TRAIN,
        seed=seed,
        rounds=rounds,
    )


@pytest.mark.slow
def test_droppeft_learns():
    res = _run("droppeft", rounds=10)
    assert res.accuracy[-3:].mean() > 0.3  # above 0.25 chance
    assert res.loss[-1] < res.loss[0]
    assert 0.2 < res.active_fraction.mean() < 0.95  # STLD actually dropping


@pytest.mark.slow
def test_droppeft_gather_mode_runs():
    res = _run("droppeft", rounds=4, stld_mode="gather")
    assert np.isfinite(res.loss).all()
    assert res.active_fraction.mean() < 0.95


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["fedlora", "fedadapter", "fedadaopt", "droppeft_b3"])
def test_baselines_run(strategy):
    peft = "adapter" if "adapter" in strategy or strategy == "fedadaopt" else "lora"
    res = _run(strategy, rounds=3, peft=peft)
    assert res.rounds == 3
    assert np.isfinite(res.loss).all()


@pytest.mark.slow
def test_fedhetlora_heterogeneous_ranks():
    res = _run("fedhetlora", rounds=3)
    assert np.isfinite(res.loss).all()


@pytest.mark.slow
def test_stld_cuts_round_time_and_memory():
    """Paper Table 1 direction: DropPEFT < plain PEFT on time and memory."""
    r_drop = _run("droppeft_b2", rounds=3)   # fixed 0.5 rate, no bandit
    r_base = _run("droppeft_b1", rounds=3)   # no STLD
    assert r_drop.cum_time_s[-1] < r_base.cum_time_s[-1]
    assert r_drop.memory_gb.max() < r_base.memory_gb.max()


def test_hetlora_pad_truncate_roundtrip(key):
    import jax.numpy as jnp

    from repro.core import peft as peft_lib
    from repro.federated import server as server_lib

    cfg = _CFG

    def check_rank(tree, rank):
        layers = tree if isinstance(tree, list) else [tree]
        for layer in layers:
            for sub in layer.values():
                for lora in sub.values():
                    assert lora["a"].shape[-1] == rank
                    assert lora["b"].shape[-2] == rank

    # stacked-native trees (the runner layout)
    p8 = peft_lib.init_peft(key, cfg, PEFTConfig(method="lora", lora_rank=8))
    p4 = server_lib.truncate_lora_rank(p8, 4)
    check_rank(p4, 4)
    agg = server_lib.hetlora_aggregate([p8, p4], [8, 4], 8)
    check_rank(agg, 8)
    # legacy list layout goes through the same converters
    p8l = peft_lib.init_peft(key, cfg, PEFTConfig(method="lora", lora_rank=8), layout="list")
    p4l = server_lib.truncate_lora_rank(p8l, 4)
    check_rank(p4l, 4)
    aggl = server_lib.hetlora_aggregate([p8l, p4l], [8, 4], 8)
    check_rank(aggl, 8)
    # both layouts aggregate to bit-identical values
    import jax

    from repro.models import stacking

    for a, b in zip(jax.tree.leaves(stacking.unstack_params(agg)), jax.tree.leaves(aggl)):
        assert jnp.array_equal(a, b)
