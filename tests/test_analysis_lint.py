"""AST lint (repro.analysis.lint_jax): rule positives via the negative
fixtures, suppression syntax, and the clean-tree invariant on src/."""
import textwrap

import pytest

from repro.analysis import fixtures, lint_jax

_LINT_RULES = sorted(r for r in fixtures.FIXTURES if r.startswith(("JXH", "PYL")))


def _lint(source):
    return lint_jax.lint_source(textwrap.dedent(source), "test.py")


@pytest.mark.parametrize("rule_id", _LINT_RULES)
def test_fixture_caught(rule_id):
    """Each deliberately-bad program fires exactly its own rule."""
    found = fixtures.run_fixture(rule_id)
    assert any(v.rule == rule_id for v in found), f"{rule_id} fixture missed"


def test_rule_catalog_complete():
    """Every registered lint rule has a negative fixture (self-test cover)."""
    assert set(_LINT_RULES) == set(lint_jax.LINT_RULES)


def test_violation_carries_location_and_hint():
    (v,) = [v for v in fixtures.run_fixture("JXH004") if v.rule == "JXH004"]
    assert "fixture.py" in v.where
    assert v.hint


# ------------------------------------------------------------- suppression
def test_inline_disable_comment():
    src = """
    def pull(rates, pos):
        return [float(rates[i]) for i in pos]  # repro-lint: disable=JXH002
    """
    assert _lint(src) == []


def test_disable_comment_on_line_above():
    src = """
    def pull(rates, pos):
        # repro-lint: disable=JXH002 — host-side list
        return [float(rates[i]) for i in pos]
    """
    assert _lint(src) == []


def test_disable_all():
    src = """
    def accumulate(x, acc=[]):  # repro-lint: disable=all
        acc.append(x)
        return acc
    """
    assert _lint(src) == []


def test_disable_other_rule_does_not_suppress():
    src = """
    def pull(rates, pos):
        return [float(rates[i]) for i in pos]  # repro-lint: disable=JXH001
    """
    assert any(v.rule == "JXH002" for v in _lint(src))


def test_noqa_spares_reexport_imports():
    src = """
    from os.path import join  # noqa: F401 (re-export)
    """
    assert _lint(src) == []


def test_rules_filter():
    src = """
    import os

    def head(list):
        return list[0]
    """
    found = lint_jax.lint_source(textwrap.dedent(src), "t.py", rules={"PYL002"})
    assert {v.rule for v in found} == {"PYL002"}


# ------------------------------------------------------------ tree is clean
def test_src_tree_is_lint_clean():
    """The shipped tree must stay lint-clean — same invariant CI enforces."""
    violations = lint_jax.lint_paths(("src", "benchmarks"))
    assert violations == [], "\n".join(v.render() for v in violations)
