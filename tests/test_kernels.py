"""Per-kernel shape/dtype sweeps + hypothesis property tests vs ref oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref

_ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


def _tol(dtype):
    return _ATOL[jnp.bfloat16] if dtype == jnp.bfloat16 else _ATOL[jnp.float32]


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kv,s,d,causal,window,bq,bk",
    [
        (2, 4, 4, 64, 32, True, None, 32, 32),
        (1, 4, 2, 100, 64, True, None, 32, 32),   # GQA + padding
        (2, 2, 2, 128, 32, True, 48, 32, 32),     # sliding window
        (1, 2, 2, 96, 64, False, None, 64, 32),   # bidirectional
        (1, 1, 1, 17, 128, True, None, 128, 128), # single block, pad
    ],
)
def test_flash_attention_sweep(key, dtype, b, h, kv, s, d, causal, window, bq, bk):
    q = jax.random.normal(key, (b, h, s, d), dtype=dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, s, d), dtype=dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, s, d), dtype=dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, block_q=bq, block_k=bk)
    expect = ops.flash_attention(q, k, v, causal=causal, window=window, impl="xla")
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), atol=_tol(dtype)
    )


@given(
    s=st.integers(4, 150),
    d=st.sampled_from([16, 32, 64]),
    h=st.integers(1, 4),
    causal=st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(s, d, h, causal):
    key = jax.random.PRNGKey(s * 7 + d)
    q = jax.random.normal(key, (1, h, s, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, h, s, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, h, s, d))
    out = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, expect, atol=3e-5)


# ------------------------------------------------------------------- wkv6
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,k,chunk", [(2, 50, 3, 16, 16), (1, 16, 1, 32, 16), (2, 33, 2, 64, 16)])
def test_wkv6_sweep(key, dtype, b, s, h, k, chunk):
    r = (0.5 * jax.random.normal(key, (b, s, h, k))).astype(dtype)
    kk = (0.5 * jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, k))).astype(dtype)
    v = (0.5 * jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, k))).astype(dtype)
    logw = jnp.clip(-jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, k))), -4.0, -1e-4).astype(dtype)
    u = (0.3 * jax.random.normal(jax.random.fold_in(key, 4), (h, k))).astype(dtype)
    out = ops.wkv6(r, kk, v, logw, u, chunk=chunk)
    expect = ref.wkv6_ref(r, kk, v, logw, u)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), atol=_tol(dtype), rtol=1e-2
    )


@given(s=st.integers(1, 70), k=st.sampled_from([8, 16]), decay=st.floats(0.1, 3.5))
@settings(max_examples=10, deadline=None)
def test_wkv6_property(s, k, decay):
    key = jax.random.PRNGKey(s * 13 + k)
    b, h = 1, 2
    r = 0.5 * jax.random.normal(key, (b, s, h, k))
    kk = 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, k))
    v = 0.5 * jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, k))
    logw = jnp.full((b, s, h, k), -decay)
    u = jnp.zeros((h, k))
    out = ops.wkv6(r, kk, v, logw, u)
    expect = ref.wkv6_ref(r, kk, v, logw, u)
    np.testing.assert_allclose(out, expect, atol=1e-4, rtol=1e-3)


# ------------------------------------------------------------------ mamba
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,d,n,chunk,dblk", [(2, 70, 32, 8, 16, 16), (1, 64, 64, 16, 64, 32)])
def test_mamba_scan_sweep(key, dtype, b, s, d, n, chunk, dblk):
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, d))).astype(dtype)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d), dtype=dtype)
    bm = jax.random.normal(jax.random.fold_in(key, 2), (b, s, n), dtype=dtype)
    cm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, n), dtype=dtype)
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 4), (d, n))).astype(jnp.float32)
    dv = jax.random.normal(jax.random.fold_in(key, 5), (d,), dtype=jnp.float32)
    out = ops.mamba_scan(dt, x, bm, cm, a, dv, chunk=chunk, d_block=dblk)
    expect = ref.mamba_scan_ref(dt, x, bm, cm, a, dv)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), atol=_tol(dtype), rtol=2e-2
    )


# ------------------------------------------------------------- lora matmul
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,r", [(100, 64, 72, 8), (32, 128, 128, 4), (128, 32, 40, 16)])
def test_lora_matmul_sweep(key, dtype, m, k, n, r):
    x = jax.random.normal(key, (m, k), dtype=dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), dtype=dtype)
    a = jax.random.normal(jax.random.fold_in(key, 2), (k, r), dtype=dtype)
    b = jax.random.normal(jax.random.fold_in(key, 3), (r, n), dtype=dtype)
    out = ops.lora_matmul(x, w, a, b, alpha=0.5, block_m=32, block_n=32)
    expect = ref.lora_matmul_ref(x, w, a, b, alpha=0.5)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32),
        atol=_tol(dtype) * 10, rtol=2e-2,
    )


@given(alpha=st.floats(0.0, 4.0))
@settings(max_examples=8, deadline=None)
def test_lora_matmul_alpha_linearity(alpha):
    key = jax.random.PRNGKey(42)
    x = jax.random.normal(key, (16, 24))
    w = jax.random.normal(jax.random.fold_in(key, 1), (24, 16))
    a = jax.random.normal(jax.random.fold_in(key, 2), (24, 4))
    b = jax.random.normal(jax.random.fold_in(key, 3), (4, 16))
    y = ops.lora_matmul(x, w, a, b, alpha=alpha, block_m=16, block_n=16)
    base = ops.lora_matmul(x, w, a, jnp.zeros_like(b), alpha=alpha, block_m=16, block_n=16)
    np.testing.assert_allclose(y - base, alpha * (x @ a) @ b, atol=1e-4)
