"""PEFT methods: partitioning, freezing, LoRA merge, per-family plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, PEFTConfig, get_config
from repro.core import peft as peft_lib
from repro.models import init_params, model_apply, stacking


@pytest.mark.parametrize("method", ["lora", "adapter", "bitfit"])
@pytest.mark.parametrize("arch", ["yi-6b", "jamba-v0.1-52b", "rwkv6-3b", "whisper-tiny"])
def test_peft_init_all_methods(arch, method, key):
    cfg = get_config(arch, smoke=True)
    pcfg = PEFTConfig(method=method, lora_rank=2, adapter_dim=8)
    tree = peft_lib.init_peft(key, cfg, pcfg)
    assert stacking.stack_size(tree) in (cfg.num_layers, None)
    n = peft_lib.count_params(tree)
    assert n > 0
    # PEFT must be tiny relative to the base model
    base = init_params(key, cfg)
    assert n < 0.2 * peft_lib.count_params(base)


@pytest.mark.parametrize("method", ["lora", "adapter", "bitfit"])
def test_peft_methods_forward_and_grads(method, key):
    cfg = get_config("yi-6b", smoke=True).replace(num_layers=2, dtype="float32")
    pcfg = PEFTConfig(method=method, lora_rank=2, adapter_dim=8)
    params = init_params(key, cfg)
    tree = peft_lib.init_peft(key, cfg, pcfg)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}

    def loss(pf):
        lo, _, _ = model_apply(params, cfg, batch, peft=pf, lora_scale=2.0)
        return jnp.mean(lo**2)

    g = jax.grad(loss)(tree)
    assert any(float(jnp.abs(x).max()) > 0 for x in jax.tree.leaves(g))


def test_lora_and_adapter_zero_init_no_op(key):
    cfg = get_config("yi-6b", smoke=True).replace(num_layers=2, dtype="float32")
    params = init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    plain, _, _ = model_apply(params, cfg, batch)
    for method in ("lora", "adapter", "bitfit"):
        tree = peft_lib.init_peft(key, cfg, PEFTConfig(method=method, lora_rank=2))
        with_peft, _, _ = model_apply(params, cfg, batch, peft=tree, lora_scale=2.0)
        np.testing.assert_allclose(plain, with_peft, atol=1e-6)


def test_merge_lora_equals_unmerged(key):
    cfg = get_config("yi-6b", smoke=True).replace(num_layers=2, dtype="float32")
    pcfg = PEFTConfig(method="lora", lora_rank=2)
    params = init_params(key, cfg)
    tree = peft_lib.init_peft(key, cfg, pcfg)
    # make LoRA non-trivial
    tree = jax.tree.map(lambda x: x + 0.05, tree)
    scale = peft_lib.lora_scale(pcfg)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    unmerged, _, _ = model_apply(params, cfg, batch, peft=tree, lora_scale=scale)
    merged_layers = peft_lib.merge_lora_into_base(params["layers"], tree, scale)
    merged, _, _ = model_apply(dict(params, layers=merged_layers), cfg, batch)
    np.testing.assert_allclose(unmerged, merged, atol=1e-4)


def test_base_params_not_differentiated(key):
    """The training step treats base params as frozen: loss grads flow only
    into the PEFT tree (value_and_grad over arg 0)."""
    from repro.configs import TrainConfig
    from repro.launch.steps import make_train_step
    from repro.optim import adamw_init

    cfg = get_config("yi-6b", smoke=True).replace(num_layers=2, dtype="float32")
    pcfg = PEFTConfig(method="lora", lora_rank=2)
    params = init_params(key, cfg)
    tree = peft_lib.init_peft(key, cfg, pcfg)
    step = make_train_step(cfg, pcfg, TrainConfig(learning_rate=1e-2))
    batch = {"tokens": jax.random.randint(key, (2, 9), 0, cfg.vocab_size)}
    new_peft, _, _ = step(params, tree, adamw_init(tree), batch, key)
    # base unchanged object-level (never updated), peft changed
    changed = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(new_peft))
    )
    assert changed
