"""The ``repro.api`` facade and the composable algorithm API.

Covers the ISSUE-2 acceptance surface: registry round-trip against the
legacy ``METHODS`` table, lifecycle hook call order (via a recording stub
algorithm), checkpoint save/resume equivalence with an uninterrupted run,
multi-seed replication, the honest ``fixed_rate=0.0`` sweep point, and the
``sustained`` time-to-accuracy option.
"""
import numpy as np
import pytest

from repro import api
from repro.checkpoint import load_state, save_state
from repro.configs import FederatedConfig, PEFTConfig, STLDConfig, TrainConfig, get_config
from repro.data import make_task
from repro.federated.algorithms import DropPEFT, FederatedAlgorithm, get_algorithm, register
from repro.federated.algorithms import base as algo_base
from repro.federated.runner import SimResult
from repro.federated.simulator import METHODS

_CFG = get_config("qwen3-1.7b", smoke=True).replace(
    num_layers=4, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
    vocab_size=128, dtype="float32",
)
_FED = FederatedConfig(num_devices=5, devices_per_round=3, local_steps=2, batch_size=8)
_TRAIN = TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2)
_TASK = make_task(num_examples=256, vocab_size=128, seed=0)


def _kw(**extra):
    kw = dict(
        cfg=_CFG,
        peft_cfg=PEFTConfig(method="lora", lora_rank=2),
        stld_cfg=STLDConfig(mode="cond", mean_rate=0.5, gather_bucket=1),
        fed_cfg=_FED,
        train_cfg=_TRAIN,
        task=_TASK,
    )
    kw.update(extra)
    return kw


# ---------------------------------------------------------------- registry
def test_registry_round_trip_matches_legacy_methods():
    assert api.list_methods() == list(METHODS)


def test_register_custom_algorithm():
    name = "_test_custom_algo"
    try:
        @register(name)
        class Custom(FederatedAlgorithm):
            pass

        assert get_algorithm(name) is Custom
        assert name in api.list_methods()
    finally:
        algo_base._REGISTRY.pop(name, None)
    with pytest.raises(KeyError):
        get_algorithm(name)


# -------------------------------------------------------------- hook order
def test_lifecycle_hook_call_order():
    calls = []

    class Recording(DropPEFT):
        def configure_round(self, state):
            calls.append("configure_round")
            return super().configure_round(state)

        def client_init(self, state, dev):
            calls.append("client_init")
            return super().client_init(state, dev)

        def cohort_step(self, state, plan):
            calls.append("cohort_step")
            return super().cohort_step(state, plan)

        def aggregate(self, state, results):
            calls.append("aggregate")
            return super().aggregate(state, results)

        def report(self, state, results):
            calls.append("report")
            return super().report(state, results)

    api.experiment(Recording(), rounds=2, **_kw())
    per_round = (
        ["configure_round"]
        + ["client_init"] * _FED.devices_per_round
        + ["cohort_step", "aggregate", "report"]
    )
    assert calls == per_round * 2


# ------------------------------------------------------------- checkpoints
def test_checkpoint_resume_matches_uninterrupted(tmp_path):
    """A run interrupted at round 2 and resumed must match an uninterrupted
    run's remaining rounds exactly (PRNG streams, bandit state, data
    samplers and history all restored)."""
    full = api.build("droppeft", seed=7, **_kw()).run(rounds=4)
    ckpt_dir = str(tmp_path / "state")
    api.build("droppeft", seed=7, checkpoint_dir=ckpt_dir, **_kw()).run(rounds=2)
    resumed = api.build(
        "droppeft", seed=7, checkpoint_dir=ckpt_dir, resume=True, **_kw()
    ).run(rounds=4)
    for f in (
        "cum_time_s", "accuracy", "loss", "rates",
        "active_fraction", "traffic_mb", "energy_j", "memory_gb",
    ):
        np.testing.assert_array_equal(getattr(full, f), getattr(resumed, f), err_msg=f)
    assert full.final_accuracy == resumed.final_accuracy


def test_save_state_round_trips_without_template(tmp_path):
    tree = {
        "key": np.arange(2, dtype=np.uint32),
        "nested": {"a": [np.ones((2, 3), np.float32), np.zeros(4, bool)]},
        "tup": (np.float32(1.5), np.arange(3)),
    }
    meta = {"round": 3, "names": ["x", "y"], "rng": {"state": 2**100}}
    out = save_state(str(tmp_path), 3, tree, meta)
    loaded, loaded_meta = load_state(out)
    assert loaded_meta == meta
    assert isinstance(loaded["nested"]["a"], list)
    assert isinstance(loaded["tup"], tuple)
    np.testing.assert_array_equal(loaded["key"], tree["key"])
    np.testing.assert_array_equal(loaded["nested"]["a"][1], tree["nested"]["a"][1])
    assert loaded["nested"]["a"][1].dtype == bool


# ---------------------------------------------------------------- facade
def test_fixed_rate_zero_is_a_real_sweep_point():
    """fixed_rate=0.0 must mean 'no dropout', not fall back to defaults."""
    res = api.experiment("droppeft_b2", fixed_rate=0.0, rounds=2, seed=1, **_kw())
    assert np.all(res.rates == 0.0)
    assert np.all(res.active_fraction == 1.0)


def test_replicate_runs_independent_seeds():
    reps = api.replicate("droppeft_b2", seeds=(0, 1), rounds=2, **_kw())
    assert len(reps) == 2
    assert not np.array_equal(reps[0].accuracy, reps[1].accuracy)


def test_replicate_preserves_instance_configuration():
    """Replication must copy, not re-instantiate: constructor configuration
    (here a custom fixed rate) carries into every seed, and the caller's
    instance is never bound or mutated."""
    algo = DropPEFT(configurator=False, fixed_rate=0.3)
    reps = api.replicate(algo, seeds=(0,), rounds=1, **_kw())
    assert np.all(reps[0].rates == 0.3)
    assert algo.ctx is None  # caller's prototype stayed unbound


def test_fixed_rate_override_does_not_mutate_caller_instance():
    algo = DropPEFT()
    api.build(algo, fixed_rate=0.3, **_kw())
    assert algo.use_configurator is True
    assert algo.fixed_rate == 0.5


def test_build_never_binds_caller_instance():
    """Two runners built from one prototype must not share (or steal) a
    bound context."""
    algo = DropPEFT(configurator=False)
    r1 = api.build(algo, seed=0, **_kw())
    r2 = api.build(algo, seed=1, **_kw())
    assert algo.ctx is None
    assert r1.algorithm is not r2.algorithm
    assert r1.algorithm.ctx is r1.ctx and r2.algorithm.ctx is r2.ctx


def test_early_stop_still_checkpoints_final_round(tmp_path):
    from repro.checkpoint import latest_state_dir, load_state

    ckpt_dir = str(tmp_path / "state")
    res = api.experiment(
        "droppeft_b2", rounds=4, target_accuracy=0.0, seed=0,
        checkpoint_dir=ckpt_dir, checkpoint_every=10, **_kw(),
    )
    assert res.rounds == 1  # stopped early, far from checkpoint_every
    _, meta = load_state(latest_state_dir(ckpt_dir))
    assert meta["round_index"] == 1


def test_configurator_state_dict_round_trip_clears_pending():
    from repro.core.configurator import OnlineConfigurator

    fresh = OnlineConfigurator(seed=0)
    snapshot = fresh.state_dict()  # taken before any next_round
    used = OnlineConfigurator(seed=0)
    used.next_round(4)  # sets _pending
    used.load_state_dict(snapshot)
    assert not hasattr(used, "_pending")
    assert used.state_dict() == snapshot


def test_resume_rejects_mismatched_device_count(tmp_path):
    ckpt_dir = str(tmp_path / "state")
    api.build("droppeft", seed=7, checkpoint_dir=ckpt_dir, **_kw()).run(rounds=1)
    other_fed = FederatedConfig(
        num_devices=4, devices_per_round=3, local_steps=2, batch_size=8
    )
    with pytest.raises(ValueError, match="devices"):
        api.build(
            "droppeft", seed=7, checkpoint_dir=ckpt_dir, resume=True,
            **_kw(fed_cfg=other_fed),
        )


def test_target_accuracy_early_stop():
    res = api.experiment("droppeft_b2", rounds=4, target_accuracy=0.0, seed=0, **_kw())
    assert res.rounds == 1  # any accuracy >= 0.0 stops after the first round


# ------------------------------------------------------------- SimResult
def _result_with_accuracy(acc):
    acc = np.asarray(acc, dtype=float)
    n = len(acc)
    z = np.zeros(n)
    return SimResult(
        rounds=n, cum_time_s=np.arange(1, n + 1, dtype=float), accuracy=acc,
        loss=z, rates=z, active_fraction=z, traffic_mb=z, energy_j=z, memory_gb=z,
    )


def test_time_to_accuracy_sustained():
    res = _result_with_accuracy([0.1, 0.6, 0.2, 0.7, 0.8])
    # first-hit: the noisy round-1 spike wins
    assert res.time_to_accuracy(0.6) == 2.0
    # sustained: accuracy dips back to 0.2 afterwards, so the claim only
    # counts from round 3 where the target is held through the end
    assert res.time_to_accuracy(0.6, sustained=True) == 4.0
    assert res.time_to_accuracy(0.9, sustained=True) is None
    assert res.time_to_accuracy(0.05, sustained=True) == 1.0
