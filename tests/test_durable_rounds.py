"""Durable rounds: crash-safe checkpointing for every scheduling policy.

The contract: killing the server at ANY round boundary and resuming from the
newest checkpoint reproduces the uninterrupted run's ``SimResult`` arrays
bit-for-bit — for sync, deadline-drop, deadline-carry, and async-buffer
alike.  The straggler-tolerant policies keep updates in flight across
aggregation boundaries, so the checkpoint carries the scheduler's event
queue, in-flight jobs, and retry bookkeeping (meta version 2); pre-durability
snapshots still load under the stateless policies and raise an actionable
error under the stateful ones.  Atomic writes mean a crash mid-save can
never poison resume: a truncated snapshot is skipped in favor of the
previous complete one.
"""
import json
import os

import numpy as np
import pytest

import jax

from repro import api
from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import FederatedConfig, PEFTConfig, STLDConfig, TrainConfig, get_config
from repro.data import make_task
from repro.federated.faults import FaultPlan, ServerKilled
from repro.federated.scheduler import ScheduleConfig

_CFG = get_config("qwen3-1.7b", smoke=True).replace(
    num_layers=4, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
    vocab_size=128, dtype="float32",
)
_FED = FederatedConfig(num_devices=6, devices_per_round=4, local_steps=2, batch_size=8)
_TRAIN = TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2)
_TASK = make_task(num_examples=256, vocab_size=128, seed=0)
_PROFILES = ["tx2", "nx", "agx", "tx2", "nx", "agx"]
_ROUNDS = 3

_POLICIES = [
    "sync",
    ScheduleConfig(policy="deadline", deadline_s=200.0, straggler="drop"),
    ScheduleConfig(policy="deadline", deadline_s=200.0, straggler="carry"),
    ScheduleConfig(policy="async-buffer", buffer_size=2, staleness_alpha=0.5),
]
_POLICY_IDS = ["sync", "deadline-drop", "deadline-carry", "async"]


def _runner(schedule, *, seed=3, **kw):
    return api.build(
        "droppeft",
        cfg=_CFG,
        peft_cfg=PEFTConfig(method="lora", lora_rank=2),
        stld_cfg=STLDConfig(mode="cond", mean_rate=0.5, gather_bucket=1),
        fed_cfg=_FED,
        train_cfg=_TRAIN,
        seed=seed,
        task=_TASK,
        schedule=schedule,
        device_profile=_PROFILES,
        cost_model=get_config("qwen3-1.7b"),
        **kw,
    )


def _result_arrays(res):
    return [
        res.cum_time_s, res.accuracy, res.loss, res.rates, res.active_fraction,
        res.traffic_mb, res.energy_j, res.memory_gb, res.arrivals,
    ]


def _assert_bit_identical(a, b):
    for x, y in zip(_result_arrays(a), _result_arrays(b)):
        np.testing.assert_array_equal(x, y)


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.parametrize("schedule", _POLICIES, ids=_POLICY_IDS)
def test_kill_at_every_boundary_resumes_bit_exact(schedule, tmp_path):
    """For every round boundary k in a 3-round run: kill after the round-k
    checkpoint (ServerKilled drill), rebuild with resume=True, finish — the
    result must equal the uninterrupted run's arrays bit-for-bit."""
    base = _runner(schedule).run(rounds=_ROUNDS)
    for kill_at in range(1, _ROUNDS):
        d = str(tmp_path / f"kill{kill_at}")
        killed = _runner(
            schedule,
            checkpoint_dir=d,
            fault_plan=FaultPlan(kill_at_rounds=(kill_at,)),
        )
        with pytest.raises(ServerKilled):
            killed.run(rounds=_ROUNDS)
        resumed = _runner(schedule, checkpoint_dir=d, resume=True)
        assert resumed.state.round_index == kill_at  # restarted mid-run
        res = resumed.run(rounds=_ROUNDS)
        _assert_bit_identical(base, res)


@pytest.mark.slow
@pytest.mark.chaos
def test_truncated_snapshot_falls_back_to_previous(tmp_path):
    """A crash mid-save (torn newest step dir) must not poison resume: the
    loader skips the invalid snapshot and resumes from the previous one,
    still reproducing the uninterrupted run bit-for-bit."""
    sched = ScheduleConfig(policy="deadline", deadline_s=200.0, straggler="carry")
    base = _runner(sched).run(rounds=_ROUNDS)

    d = str(tmp_path / "ckpt")
    killed = _runner(
        sched, checkpoint_dir=d, fault_plan=FaultPlan(kill_at_rounds=(2,))
    )
    with pytest.raises(ServerKilled):
        killed.run(rounds=_ROUNDS)
    steps = sorted(os.listdir(d))
    assert steps == ["step_00000001", "step_00000002"]
    # tear the newest snapshot the way a mid-write crash would (the atomic
    # writer makes this unreachable in-process; simulate a torn copy)
    npz = os.path.join(d, steps[-1], "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)

    resumed = _runner(sched, checkpoint_dir=d, resume=True)
    assert resumed.state.round_index == 1  # fell back to step 1
    res = resumed.run(rounds=_ROUNDS)
    _assert_bit_identical(base, res)


@pytest.mark.slow
@pytest.mark.chaos
def test_v1_snapshot_backcompat(tmp_path):
    """A pre-durability (meta version 1) snapshot — no scheduler section —
    still resumes under the stateless policies, and raises an actionable
    error (not a KeyError) under a policy that keeps updates in flight."""
    d = str(tmp_path / "ckpt")
    killed = _runner(
        "sync", checkpoint_dir=d, fault_plan=FaultPlan(kill_at_rounds=(1,))
    )
    with pytest.raises(ServerKilled):
        killed.run(rounds=_ROUNDS)
    # strip the v2 fields from the newest manifest: exactly what a snapshot
    # written before the durability layer looks like
    step_dir = os.path.join(d, sorted(os.listdir(d))[-1])
    manifest_path = os.path.join(step_dir, "manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    for key in ("meta_version", "scheduler", "fault_plan"):
        manifest["meta"].pop(key, None)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f)

    # stateful policy: actionable refusal naming the policy and the versions
    # (checked first — the sync resume below writes fresh v2 snapshots into
    # the same dir, which would mask the v1 manifest)
    with pytest.raises(ValueError, match="predates durable in-flight state"):
        _runner(
            ScheduleConfig(policy="async-buffer", buffer_size=2),
            checkpoint_dir=d,
            resume=True,
        )

    # stateless policy: loads fine and finishes bit-identically to the
    # uninterrupted run
    base = _runner("sync").run(rounds=_ROUNDS)
    resumed = _runner("sync", checkpoint_dir=d, resume=True)
    _assert_bit_identical(base, resumed.run(rounds=_ROUNDS))


def test_checkpoint_roundtrips_in_flight_jobs(tmp_path):
    """Unit-level: state_dict/load_state_dict round-trip the scheduler's
    heap, jobs, logs, and retry bookkeeping exactly (no run loop)."""
    runner = _runner(ScheduleConfig(policy="async-buffer", buffer_size=2))
    sched = runner.scheduler
    sched._dispatch(size=4)  # four in-flight jobs, nothing aggregated yet
    sched.event_log.append((0, 1, 12.5))
    sched.fault_log.append({"round": 0, "dev": 1, "reason": "dropout"})
    sched._backoff[2] = 99.5
    sched._fail_count[2] = 3

    # through the real npz/json serialization, not just in-memory
    ckpt_lib.save_state(str(tmp_path), 0, *sched.state_dict())
    jobs_arrays, meta = ckpt_lib.load_state(ckpt_lib.latest_state_dir(str(tmp_path)))
    other = _runner(ScheduleConfig(policy="async-buffer", buffer_size=2))
    other.scheduler.load_state_dict(jobs_arrays, meta)

    assert sorted(other.scheduler._jobs) == sorted(sched._jobs)
    assert sorted(other.scheduler._heap) == sorted(sched._heap)
    assert other.scheduler.event_log == sched.event_log
    assert other.scheduler.fault_log == sched.fault_log
    assert other.scheduler._backoff == sched._backoff
    assert other.scheduler._fail_count == sched._fail_count
    for dev, job in sched._jobs.items():
        twin = other.scheduler._jobs[dev]
        for f in ("rate", "version", "dispatch_round", "cohort_pos",
                  "dispatch_time", "duration", "finish", "accuracy",
                  "active_frac", "compute_s", "comm_s", "energy_j",
                  "traffic_mb", "memory_gb", "failed"):
            assert getattr(twin, f) == getattr(job, f), f
        for a, b in zip(jax.tree.leaves(job.peft), jax.tree.leaves(twin.peft)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(job.mask, twin.mask)
