"""FROZEN pre-refactor ``FederatedSimulator`` (PR-1 state) — parity baseline.

This is a verbatim copy of ``src/repro/federated/simulator.py`` as it stood
before the hook-based algorithm API replaced it (commit 2b2028d).  It exists
ONLY so ``tests/test_method_parity.py`` can prove the new
``ExperimentRunner`` reproduces the old ``run()`` SimResult arrays
bit-for-bit for every registered method.  Do not import it from product
code, and do not "fix" it — its behavior is the contract.

(The only permitted deviation from the verbatim copy: ``init_peft`` calls
pin ``layout="list"`` — the sole layout that existed pre-refactor — so this
baseline keeps exercising the per-layer list code paths after the
stacked-native layout became the library default.  The emitted values are
unchanged; only the container layout is pinned.)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import peft as peft_lib
from repro.core import stld as stld_lib
from repro.core.configurator import OnlineConfigurator
from repro.data import DeviceDataset, dirichlet_partition, make_task
from repro.federated import server as server_lib
from repro.federated.client import make_client_fns
from repro.federated.system_model import SystemModel, sample_bandwidth, sample_device
from repro.models.registry import default_stack_mode, init_params
from repro.optim import adamw_init


@dataclass
class Strategy:
    """Which paper method/ablation to run."""

    name: str = "droppeft"
    stld: bool = True
    configurator: bool = True
    ptls: bool = True
    fixed_rate: float = 0.5          # used when configurator is off
    hetlora: bool = False            # FedHetLoRA baseline
    hetlora_ranks: tuple = (4, 8, 16)
    adaopt: bool = False             # FedAdaOPT progressive-depth baseline
    adaopt_grow_every: int = 5


METHODS: Dict[str, Strategy] = {
    "fedlora": Strategy("fedlora", stld=False, configurator=False, ptls=False),
    "fedadapter": Strategy("fedadapter", stld=False, configurator=False, ptls=False),
    "fedhetlora": Strategy(
        "fedhetlora", stld=False, configurator=False, ptls=False, hetlora=True
    ),
    "fedadaopt": Strategy(
        "fedadaopt", stld=False, configurator=False, ptls=False, adaopt=True
    ),
    "droppeft": Strategy("droppeft"),
    "droppeft_b1": Strategy("droppeft_b1", stld=False),            # w/o STLD
    "droppeft_b2": Strategy("droppeft_b2", configurator=False),    # fixed rate
    "droppeft_b3": Strategy("droppeft_b3", ptls=False),            # w/o PTLS
}


@dataclass
class SimResult:
    rounds: int
    cum_time_s: np.ndarray           # (R,)
    accuracy: np.ndarray             # (R,) mean cohort val accuracy
    loss: np.ndarray                 # (R,)
    rates: np.ndarray                # (R,) mean dropout rate used
    active_fraction: np.ndarray      # (R,) measured E[L~]/L
    traffic_mb: np.ndarray           # (R,) cohort total
    energy_j: np.ndarray             # (R,) cohort total
    memory_gb: np.ndarray            # (R,) max per-device footprint
    final_accuracy: float = 0.0

    def time_to_accuracy(self, target: float) -> Optional[float]:
        hit = np.where(self.accuracy >= target)[0]
        return float(self.cum_time_s[hit[0]]) if len(hit) else None


class FederatedSimulator:
    def __init__(
        self,
        cfg,
        peft_cfg,
        stld_cfg,
        fed_cfg,
        train_cfg,
        *,
        strategy: Strategy | str = "droppeft",
        task=None,
        cost_cfg=None,
        seed: int = 0,
        cohort_mode: str = "auto",
    ):
        self.cfg = cfg
        self.peft_cfg = peft_cfg
        self.stld_cfg = stld_cfg
        self.fed_cfg = fed_cfg
        self.train_cfg = train_cfg
        self.strategy = METHODS[strategy] if isinstance(strategy, str) else strategy
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)

        if cohort_mode not in ("auto", "batched", "sequential"):
            raise ValueError(f"unknown cohort_mode {cohort_mode!r}")
        if cohort_mode == "batched" and self.strategy.hetlora:
            raise ValueError(
                "cohort_mode='batched' cannot stack hetlora's rank-heterogeneous "
                "PEFT trees; use 'sequential' (or 'auto')"
            )
        if cohort_mode == "auto":
            cohort_mode = "sequential" if self.strategy.hetlora else "batched"
        self.cohort_mode = cohort_mode

        self.task = task or make_task(vocab_size=cfg.vocab_size, seed=seed)
        parts = dirichlet_partition(
            self.task.labels, fed_cfg.num_devices, fed_cfg.dirichlet_alpha, seed=seed
        )
        self.devices = [
            DeviceDataset(self.task, idx, seed=seed + i) for i, idx in enumerate(parts)
        ]
        self.device_profile = [sample_device(self.rng) for _ in range(fed_cfg.num_devices)]
        # fixed val pad size so the jit'd cohort_evaluate signature is stable
        self._val_pad = max(len(d.val_batch()["labels"]) for d in self.devices)

        self.key, k1, k2 = jax.random.split(self.key, 3)
        self.base_params = init_params(k1, cfg, layout="list")
        self.global_peft = peft_lib.init_peft(k2, cfg, peft_cfg, layout="list")
        self.device_peft: Dict[int, list] = {}
        stack_mode = default_stack_mode(cfg)
        self.client = make_client_fns(
            cfg, peft_cfg, stld_cfg, train_cfg, stack_mode=stack_mode
        )
        self.local_round, self.evaluate = self.client.local_round, self.client.evaluate
        # server aggregation is pure tree math: jit it so a round's
        # aggregation is one dispatch instead of hundreds of tiny ops
        self._fedavg = jax.jit(server_lib.fedavg)
        self._ptls_aggregate = jax.jit(server_lib.ptls_aggregate)
        self.system = SystemModel(cost_cfg or cfg, peft_cfg)
        self.configurator = (
            OnlineConfigurator(
                rate_grid=fed_cfg.rate_grid,
                num_candidates=fed_cfg.num_candidates,
                explore_rate=fed_cfg.explore_rate,
                explore_interval=fed_cfg.explore_interval,
                window_size=fed_cfg.window_size,
                seed=seed,
            )
            if self.strategy.configurator and self.strategy.stld
            else None
        )
        self._prev_acc: Dict[int, float] = {}
        self._last_mask: Dict[int, np.ndarray] = {}
        self._unstack_cache: Dict[int, object] = {}
        self._stack_cache: Dict[int, object] = {}
        self._val_cache: Dict[int, dict] = {}
        self._global_step = 0
        if self.strategy.hetlora:
            # per-device LoRA rank from device capability tier
            tiers = {"tx2": 0, "nx": 1, "agx": 2}
            self.device_rank = [
                self.strategy.hetlora_ranks[tiers[p]] for p in self.device_profile
            ]
            self.max_rank = max(self.strategy.hetlora_ranks)
            # global tree holds the max rank
            self.global_peft = peft_lib.init_peft(
                k2, cfg,
                peft_cfg.__class__(**{**peft_cfg.__dict__, "lora_rank": self.max_rank}),
                layout="list",
            )
            self._het_fns = {}
            for r in set(self.device_rank):
                pc = peft_cfg.__class__(**{**peft_cfg.__dict__, "lora_rank": r})
                self._het_fns[r] = make_client_fns(
                    cfg, pc, stld_cfg, train_cfg, stack_mode=stack_mode
                )

    # ------------------------------------------------------------------ run
    def run(self, rounds: Optional[int] = None, target_accuracy: Optional[float] = None) -> SimResult:
        fed = self.fed_cfg
        rounds = rounds or fed.rounds
        hist = {k: [] for k in (
            "time", "acc", "loss", "rate", "active", "traffic", "energy", "memory"
        )}
        cum_time = 0.0
        num_classes = jnp.arange(self.task.num_classes)

        for rnd in range(rounds):
            cohort = [
                int(d)
                for d in self.rng.choice(
                    fed.num_devices,
                    size=min(fed.devices_per_round, fed.num_devices),
                    replace=False,
                )
            ]
            n = len(cohort)
            if self.configurator is not None:
                rates = self.configurator.next_round(n)
            elif self.strategy.stld:
                rates = [self.strategy.fixed_rate] * n
            else:
                rates = [0.0] * n

            adaopt_depth = self.cfg.num_layers
            if self.strategy.adaopt:
                adaopt_depth = min(
                    self.cfg.num_layers,
                    2 + (rnd // self.strategy.adaopt_grow_every) * 2,
                )

            outs = self._run_cohort(cohort, rates, num_classes, adaopt_depth)
            round_accs = [acc for _, _, _, acc in outs]
            round_losses = [float(metrics["loss"]) for _, metrics, _, _ in outs]
            active_fracs = [
                float(metrics["active_layers"]) / self.cfg.num_layers
                for _, metrics, _, _ in outs
            ]

            # share masks: batched importance -> per-device mask in one call
            if self.strategy.ptls:
                k = max(1, int(fed.ptls_share_fraction * self.cfg.num_layers))
                importances = np.stack([np.asarray(imp) for _, _, imp, _ in outs])
                masks = np.asarray(server_lib.cohort_shared_masks(importances, k))
            else:
                masks = np.ones((n, self.cfg.num_layers), dtype=bool)

            client_updates = [peft_i for peft_i, _, _, _ in outs]
            client_ranks = (
                [self.device_rank[dev] for dev in cohort] if self.strategy.hetlora else []
            )
            for i, dev in enumerate(cohort):
                self.device_peft[dev] = client_updates[i]
                self._last_mask[dev] = masks[i]

            # vectorized system-model accounting over the cohort
            bandwidths = np.array([sample_bandwidth(self.rng) for _ in cohort])
            cost = self.system.cohort_round_cost(
                devices=[self.device_profile[dev] for dev in cohort],
                bandwidth_mbps=bandwidths,
                batch=fed.batch_size,
                seq=self.task.seq_len,
                local_steps=fed.local_steps,
                peft=True,
                active_fraction=(
                    np.asarray(active_fracs) if self.strategy.stld else np.ones(n)
                ),
                share_fraction=masks.mean(axis=1),
            )
            round_times = cost.total_time_s

            # ---------------------------------------------------- aggregate
            if self.strategy.hetlora:
                self.global_peft = server_lib.hetlora_aggregate(
                    client_updates, client_ranks, self.max_rank
                )
            elif self.strategy.ptls:
                self.global_peft = self._ptls_aggregate(
                    client_updates, masks, self.global_peft
                )
            else:
                self.global_peft = self._fedavg(client_updates)

            # ------------------------------------------------------- report
            round_wall = float(round_times.max())  # synchronous round
            cum_time += round_wall
            mean_acc = float(np.mean(round_accs))
            if self.configurator is not None:
                gains = []
                for i, dev in enumerate(cohort):
                    prev = self._prev_acc.get(dev, 1.0 / self.task.num_classes)
                    gains.append(max(round_accs[i] - prev, 0.0))
                self.configurator.report(rates, gains, round_times)
            for i, dev in enumerate(cohort):
                self._prev_acc[dev] = round_accs[i]

            hist["time"].append(cum_time)
            hist["acc"].append(mean_acc)
            hist["loss"].append(float(np.mean(round_losses)))
            hist["rate"].append(float(np.mean(rates)))
            hist["active"].append(float(np.mean(active_fracs)))
            hist["traffic"].append(float(cost.traffic_mb.sum()))
            hist["energy"].append(float(cost.energy_j.sum()))
            hist["memory"].append(float(cost.memory_gb.max()))

            if target_accuracy is not None and mean_acc >= target_accuracy:
                break

        result = SimResult(
            rounds=len(hist["time"]),
            cum_time_s=np.asarray(hist["time"]),
            accuracy=np.asarray(hist["acc"]),
            loss=np.asarray(hist["loss"]),
            rates=np.asarray(hist["rate"]),
            active_fraction=np.asarray(hist["active"]),
            traffic_mb=np.asarray(hist["traffic"]),
            energy_j=np.asarray(hist["energy"]),
            memory_gb=np.asarray(hist["memory"]),
        )
        result.final_accuracy = self.final_accuracy(num_classes)
        return result

    # ------------------------------------------------------------ internals
    def _device_start_peft(self, dev: int):
        """Shared layers from the global model; personalized layers local."""
        if dev not in self.device_peft or not self.strategy.ptls:
            if self.strategy.hetlora:
                return server_lib.truncate_lora_rank(self.global_peft, self.device_rank[dev])
            return self.global_peft
        own = self.device_peft[dev]
        # device keeps its own layers; refresh from global (download)
        mixed = []
        for l in range(self.cfg.num_layers):
            mixed.append(self.global_peft[l] if self._is_shared(dev, l) else own[l])
        return mixed

    def _is_shared(self, dev: int, l: int) -> bool:
        mask = self._last_mask.get(dev)
        return True if mask is None else bool(mask[l])

    def _run_cohort(self, cohort, rates, num_classes, adaopt_depth):
        """Train one round's cohort; returns a list (len N) of per-device
        ``(peft, metrics, importance, accuracy)`` tuples.  Both modes draw
        from identical PRNG streams: one split fan-out for the per-device
        keys, per-device global-step offsets in cohort order."""
        fed = self.fed_cfg
        n = len(cohort)
        start_pefts = [self._device_start_peft(dev) for dev in cohort]
        self.key, *keys = jax.random.split(self.key, n + 1)
        gsteps = [self._global_step + i * fed.local_steps for i in range(n)]
        self._global_step += n * fed.local_steps

        if self.cohort_mode == "batched":
            outs = self._run_cohort_batched(
                cohort, rates, start_pefts, keys, gsteps, num_classes, adaopt_depth
            )
        else:
            outs = [
                self._run_device(
                    cohort[i], rates[i], start_pefts[i], keys[i], gsteps[i],
                    num_classes, adaopt_depth,
                )
                for i in range(n)
            ]
        return outs

    def _adaopt_truncate(self, peft_i, start_peft, adaopt_depth: int):
        """Progressive depth (FedAdaOPT): layers beyond the active depth keep
        their incoming values — their adapter updates are discarded BEFORE
        evaluation, so reported accuracy measures the retained model."""
        return [
            peft_i[l] if l < adaopt_depth else start_peft[l]
            for l in range(self.cfg.num_layers)
        ]

    def _stacked_train_batches(self, dev: int):
        fed = self.fed_cfg
        batches = list(self.devices[dev].train_batches(fed.batch_size, fed.local_steps))
        return {
            k: np.stack([b[k] for b in batches]) for k in ("tokens", "targets", "mask")
        }

    def _padded_val_batch(self, dev: int):
        """Val batch padded to the cohort-wide size with a validity mask.
        Val splits are static, so the padded batch is built once per device."""
        cached = self._val_cache.get(dev)
        if cached is None:
            val = self.devices[dev].val_batch()
            b = len(val["labels"])
            pad = self._val_pad - b
            valid = np.zeros((self._val_pad,), dtype=np.float32)
            valid[:b] = 1.0
            cached = {
                "tokens": np.pad(val["tokens"], ((0, pad), (0, 0))),
                "labels": np.pad(val["labels"], (0, pad)),
                "valid": valid,
            }
            self._val_cache[dev] = cached
        return cached

    def _static_active_counts(self, rates) -> List[Optional[int]]:
        """Gather-mode static active-layer count per device (None in cond
        mode).  Static counts partition the batched cohort into groups."""
        if self.stld_cfg.mode == "gather" and self.strategy.stld:
            return [
                stld_lib.static_active_count(
                    rate,
                    self.cfg.num_layers,
                    self.stld_cfg.gather_bucket,
                    self.stld_cfg.min_active_layers,
                )
                for rate in rates
            ]
        return [None] * len(rates)

    def _run_cohort_batched(
        self, cohort, rates, start_pefts, keys, gsteps, num_classes, adaopt_depth
    ):
        """One (or few, in gather mode) jit'd calls train the whole cohort."""
        n = len(cohort)
        adaopt = self.strategy.adaopt and adaopt_depth < self.cfg.num_layers
        batch_list = [self._stacked_train_batches(dev) for dev in cohort]
        val_list = [self._padded_val_batch(dev) for dev in cohort]
        num_active = self._static_active_counts(rates)

        outs: List[Optional[tuple]] = [None] * n
        for na in dict.fromkeys(num_active):
            pos = [i for i in range(n) if num_active[i] == na]
            peft_stack = self._stack_trees([start_pefts[i] for i in pos])
            batch_stack = {
                k: jnp.asarray(np.stack([batch_list[i][k] for i in pos]))
                for k in ("tokens", "targets", "mask")
            }
            rate_arr = jnp.asarray([float(rates[i]) for i in pos], dtype=jnp.float32)
            key_arr = jnp.stack([keys[i] for i in pos])
            gstep_arr = jnp.asarray([gsteps[i] for i in pos], dtype=jnp.int32)
            val_args = (
                jnp.asarray(np.stack([val_list[i]["tokens"] for i in pos])),
                jnp.asarray(np.stack([val_list[i]["labels"] for i in pos])),
                jnp.asarray(np.stack([val_list[i]["valid"] for i in pos])),
            )
            if adaopt:
                # progressive depth discards deep-layer updates before eval,
                # so train and eval cannot be fused: train, truncate the
                # stacked tree per layer, then evaluate the retained model
                peft_out, metrics, importances = self.client.cohort_round(
                    self.base_params, peft_stack, batch_stack,
                    rate_arr, key_arr, gstep_arr, num_active=na,
                )
                peft_out = self._adaopt_truncate(peft_out, peft_stack, adaopt_depth)
                accs = self.client.cohort_evaluate(
                    self.base_params, peft_out, *val_args, num_classes
                )
            else:
                peft_out, metrics, importances, accs = self.client.cohort_round_eval(
                    self.base_params,
                    peft_stack,
                    batch_stack,
                    rate_arr,
                    key_arr,
                    gstep_arr,
                    *val_args,
                    num_classes,
                    num_active=na,
                )
            # one jit'd unstack + one host pull: per-leaf x[j] slicing and
            # per-device float() syncs would cost hundreds of tiny dispatches
            peft_list = self._unstack_tree(peft_out, len(pos))
            metrics_np, imps_np, accs_np = jax.device_get((metrics, importances, accs))
            for j, i in enumerate(pos):
                dev_metrics = {k: v[j] for k, v in metrics_np.items()}
                outs[i] = (peft_list[j], dev_metrics, imps_np[j], float(accs_np[j]))
        return outs

    def _stack_trees(self, trees):
        """Stack a list of identically-shaped pytrees along a new leading
        axis in ONE jit'd dispatch (cached per cohort-group size)."""
        n = len(trees)
        fn = self._stack_cache.get(n)
        if fn is None:
            fn = jax.jit(lambda *ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts))
            self._stack_cache[n] = fn
        return fn(*trees)

    def _unstack_tree(self, tree, n: int):
        """Split a leading-(n,) stacked pytree into n pytrees in ONE jit'd
        dispatch (cached per cohort-group size)."""
        fn = self._unstack_cache.get(n)
        if fn is None:
            fn = jax.jit(lambda t: tuple(jax.tree.map(lambda x: x[j], t) for j in range(n)))
            self._unstack_cache[n] = fn
        return fn(tree)

    def _run_device(
        self, dev: int, rate: float, start_peft, key, gstep: int, num_classes, adaopt_depth
    ):
        fed = self.fed_cfg
        if self.strategy.hetlora:
            fns = self._het_fns[self.device_rank[dev]]
            local_round, evaluate = fns.local_round, fns.evaluate
        else:
            local_round, evaluate = self.local_round, self.evaluate

        stacked = {
            k: jnp.asarray(v) for k, v in self._stacked_train_batches(dev).items()
        }
        opt_state = adamw_init(start_peft)
        num_active = self._static_active_counts([rate])[0]
        peft_i, _, metrics, importance = local_round(
            self.base_params,
            start_peft,
            opt_state,
            stacked,
            jnp.asarray(rate, dtype=jnp.float32),
            key,
            jnp.asarray(gstep, dtype=jnp.int32),
            num_active=num_active,
        )
        if self.strategy.adaopt and adaopt_depth < self.cfg.num_layers:
            peft_i = self._adaopt_truncate(peft_i, start_peft, adaopt_depth)

        val = self.devices[dev].val_batch()
        acc = float(
            evaluate(
                self.base_params,
                peft_i,
                jnp.asarray(val["tokens"]),
                jnp.asarray(val["labels"]),
                num_classes,
            )
        )
        return peft_i, metrics, importance, acc

    def final_accuracy(self, num_classes) -> float:
        """Paper protocol: mean accuracy across ALL devices' local test sets,
        each device using its personalized model (global for non-participants)."""
        if self.cohort_mode == "batched" and not self.strategy.hetlora:
            devs = range(self.fed_cfg.num_devices)
            peft_stack = self._stack_trees(
                [self.device_peft.get(dev, self.global_peft) for dev in devs]
            )
            vals = [self._padded_val_batch(dev) for dev in devs]
            accs = self.client.cohort_evaluate(
                self.base_params,
                peft_stack,
                jnp.asarray(np.stack([v["tokens"] for v in vals])),
                jnp.asarray(np.stack([v["labels"] for v in vals])),
                jnp.asarray(np.stack([v["valid"] for v in vals])),
                num_classes,
            )
            return float(np.mean(np.asarray(accs)))
        accs = []
        for dev in range(self.fed_cfg.num_devices):
            peft_d = self.device_peft.get(dev, self.global_peft)
            if self.strategy.hetlora and dev not in self.device_peft:
                peft_d = server_lib.truncate_lora_rank(self.global_peft, self.device_rank[dev])
            evaluate = (
                self._het_fns[self.device_rank[dev]].evaluate
                if self.strategy.hetlora
                else self.evaluate
            )
            val = self.devices[dev].val_batch()
            accs.append(
                float(
                    evaluate(
                        self.base_params,
                        peft_d,
                        jnp.asarray(val["tokens"]),
                        jnp.asarray(val["labels"]),
                        num_classes,
                    )
                )
            )
        return float(np.mean(accs))
