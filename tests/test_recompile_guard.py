"""Recompilation guard: the compile counter, the budget context manager, and
the steady-state invariant for the sync schedule at smoke scale."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.recompile_guard import (
    DEFAULT_BUDGETS,
    CompilationCounter,
    RecompileBudgetExceeded,
    check_experiment_recompiles,
    recompile_guard,
)


def _fresh_fn():
    # a lambda defined at call time never hits jit's in-memory cache
    return jax.jit(lambda x: x * 3.0 + 1.0)


def test_counter_sees_fresh_compile_and_not_cache_hits():
    f = _fresh_fn()
    with CompilationCounter() as c:
        f(jnp.float32(1.0))
    assert c.count >= 1
    with CompilationCounter() as c2:
        f(jnp.float32(2.0))  # same shape/dtype: cached executable
    assert c2.count == 0


def test_counter_unregisters_on_exit():
    with CompilationCounter() as c:
        pass
    before = c.count
    _fresh_fn()(jnp.float32(1.0))  # compile AFTER the context closed
    assert c.count == before


def test_guard_raises_on_static_arg_churn():
    f = jax.jit(lambda x, s: x + s, static_argnums=(1,))
    with pytest.raises(RecompileBudgetExceeded, match="budget"):
        with recompile_guard(1, label="churn"):
            for s in range(4):
                f(jnp.float32(0.0), 1000 + s)


def test_guard_passes_within_budget():
    f = _fresh_fn()
    with recompile_guard(2, label="single compile") as c:
        f(jnp.float32(1.0))
        f(jnp.float32(2.0))
    assert c.count <= 2


def test_fixture_reports_violation():
    from repro.analysis import fixtures

    found = fixtures.run_fixture("recompile")
    assert any(v.rule == "recompile" for v in found)


# -------------------------------------------------- steady-state invariant
def test_sync_schedule_steady_state_compiles_nothing():
    """After warmup, extending a sync-schedule run must hit only cached
    executables (budget 0) — the invariant CI enforces via the CLI."""
    assert DEFAULT_BUDGETS["sync"] == 0
    violations = check_experiment_recompiles(policies=("sync",))
    assert violations == [], "\n".join(v.render() for v in violations)


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["deadline", "async-buffer"])
def test_other_schedules_within_budget(policy):
    violations = check_experiment_recompiles(policies=(policy,))
    assert violations == [], "\n".join(v.render() for v in violations)
