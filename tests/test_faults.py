"""Fault-injection semantics: determinism, screening, graceful degradation.

The :class:`~repro.federated.faults.FaultInjector` draws every fault from a
generator keyed ``(seed, kind, round, dev)``, so fault sequences are a pure
function of the plan and the dispatch coordinates — identical across runs,
across batched/sequential cohort modes, and independent of draw order.  A
zero-fault plan must be bit-transparent, and under real faults every policy
must complete with a finite global PEFT (rejected updates screened, burned
compute billed, dropped devices retried after backoff).
"""
import dataclasses
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.configs import FederatedConfig, PEFTConfig, STLDConfig, TrainConfig, get_config
from repro.data import make_task
from repro.federated import server as server_lib
from repro.federated.faults import (
    FaultInjector,
    FaultPlan,
    ServerKilled,
    resolve_fault_plan,
)
from repro.federated.scheduler import ScheduleConfig

from _hypothesis_fallback import given, settings, st

_CFG = get_config("qwen3-1.7b", smoke=True).replace(
    num_layers=4, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
    vocab_size=128, dtype="float32",
)
_FED = FederatedConfig(num_devices=6, devices_per_round=4, local_steps=2, batch_size=8)
_TRAIN = TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2)
_TASK = make_task(num_examples=256, vocab_size=128, seed=0)
_PROFILES = ["tx2", "nx", "agx", "tx2", "nx", "agx"]
_ROUNDS = 3

_POLICIES = [
    "sync",
    ScheduleConfig(policy="deadline", deadline_s=200.0, straggler="drop"),
    ScheduleConfig(policy="deadline", deadline_s=200.0, straggler="carry"),
    ScheduleConfig(policy="async-buffer", buffer_size=2, staleness_alpha=0.5),
]
_POLICY_IDS = ["sync", "deadline-drop", "deadline-carry", "async"]


def _runner(schedule, *, cohort_mode="batched", seed=3, fault_plan=None, **kw):
    return api.build(
        "droppeft",
        cfg=_CFG,
        peft_cfg=PEFTConfig(method="lora", lora_rank=2),
        stld_cfg=STLDConfig(mode="cond", mean_rate=0.5, gather_bucket=1),
        fed_cfg=_FED,
        train_cfg=_TRAIN,
        seed=seed,
        task=_TASK,
        cohort_mode=cohort_mode,
        schedule=schedule,
        device_profile=_PROFILES,
        cost_model=get_config("qwen3-1.7b"),
        fault_plan=fault_plan,
        **kw,
    )


def _result_arrays(res):
    return [
        res.cum_time_s, res.accuracy, res.loss, res.rates, res.active_fraction,
        res.traffic_mb, res.energy_j, res.memory_gb, res.arrivals,
    ]


def _finite_tree(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree))


# ------------------------------------------------------------- plan/injector
def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(dropout_prob=1.5)
    with pytest.raises(ValueError):
        FaultPlan(dropout_frac=(0.0, 0.5))  # lo must be > 0
    with pytest.raises(ValueError):
        FaultPlan(bandwidth_collapse_factor=0.5)
    with pytest.raises(ValueError):
        FaultPlan(retry_backoff_s=100.0, max_backoff_s=10.0)
    assert not FaultPlan().any_faults
    assert FaultPlan(dropout_prob=0.1).any_faults
    assert FaultPlan(kill_at_rounds=(2,)).any_faults


def test_fault_plan_json_roundtrip(tmp_path):
    plan = FaultPlan(
        seed=7, dropout_prob=0.25, nan_updates=((1, 2),),
        churn=((3, 10.0, 50.0),), kill_at_rounds=(4,),
    )
    assert FaultPlan.from_json(plan.to_json()) == plan
    path = tmp_path / "plan.json"
    path.write_text(plan.to_json())
    assert FaultPlan.from_file(str(path)) == plan
    assert resolve_fault_plan(str(path)) == plan
    assert resolve_fault_plan({"seed": 7, "dropout_prob": 0.25}) == FaultPlan(
        seed=7, dropout_prob=0.25
    )
    assert resolve_fault_plan(None) is None
    with pytest.raises(TypeError):
        resolve_fault_plan(42)


def test_injector_draws_are_order_independent():
    """Every fault outcome is a pure function of (seed, kind, round, dev):
    querying coordinates in any order — or twice — changes nothing."""
    inj = FaultInjector(FaultPlan(seed=11, dropout_prob=0.5, nan_update_prob=0.3))
    coords = [(r, d) for r in range(5) for d in range(6)]
    forward = [(inj.dropout_at(r, d), inj.corrupts(r, d)) for r, d in coords]
    backward = [
        (inj.dropout_at(r, d), inj.corrupts(r, d)) for r, d in reversed(coords)
    ]
    assert forward == backward[::-1]
    # distinct seeds decorrelate
    other = FaultInjector(FaultPlan(seed=12, dropout_prob=0.5, nan_update_prob=0.3))
    assert forward != [
        (other.dropout_at(r, d), other.corrupts(r, d)) for r, d in coords
    ]


def test_injector_pinned_nan_and_probability():
    inj = FaultInjector(FaultPlan(seed=0, nan_updates=((2, 4),)))
    assert inj.corrupts(2, 4)
    assert not inj.corrupts(2, 3)
    # with p=1 every coordinate corrupts; dropout frac stays inside its range
    inj = FaultInjector(
        FaultPlan(seed=0, nan_update_prob=1.0, dropout_prob=1.0,
                  dropout_frac=(0.3, 0.9))
    )
    for r, d in [(0, 0), (3, 5)]:
        assert inj.corrupts(r, d)
        frac = inj.dropout_at(r, d)
        assert frac is not None and 0.3 <= frac <= 0.9


def test_backoff_exponential_and_capped():
    inj = FaultInjector(FaultPlan(retry_backoff_s=30.0, max_backoff_s=200.0))
    assert inj.backoff_s(1) == 30.0
    assert inj.backoff_s(2) == 60.0
    assert inj.backoff_s(3) == 120.0
    assert inj.backoff_s(4) == 200.0  # capped
    assert inj.backoff_s(50) == 200.0


def test_churn_windows():
    inj = FaultInjector(FaultPlan(churn=((2, 10.0, 50.0), (2, 80.0, 90.0))))
    assert not inj.unavailable(2, 9.0)
    assert inj.unavailable(2, 10.0)
    assert inj.unavailable(2, 49.0)
    assert not inj.unavailable(2, 50.0)
    assert not inj.unavailable(3, 20.0)
    assert inj.next_rejoin(2, 20.0) == 50.0
    assert inj.next_rejoin(2, 85.0) == 90.0
    assert inj.next_rejoin(2, 60.0) is None


# ----------------------------------------------------- staleness-weight props
@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n=st.integers(min_value=1, max_value=32),
    alpha=st.floats(min_value=0.0, max_value=8.0),
)
def test_staleness_weights_finite_and_normalized(seed, n, alpha):
    """Under any dropout pattern — i.e. any achievable staleness vector,
    including extreme lags from repeatedly-dropped devices — the staleness
    weights stay finite, strictly positive, and sum to one."""
    rng = np.random.default_rng(seed)
    staleness = rng.integers(0, 10_000, size=n)
    w = server_lib.staleness_weights(staleness, alpha)
    assert w.shape == (n,)
    assert np.all(np.isfinite(w))
    assert np.all(w > 0)
    assert math.isclose(float(w.sum()), 1.0, rel_tol=1e-12)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_screen_finite_is_identity_on_finite(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    tree = {"a": x, "b": {"c": x * 3}}
    out = server_lib.screen_finite(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # corrupting one leaf screens only that leaf, to the fallback
    bad = {"a": x.at[0, 0].set(jnp.nan), "b": {"c": x * 3}}
    fb = {"a": jnp.full_like(x, 7.0), "b": {"c": jnp.zeros_like(x)}}
    out = server_lib.screen_finite(bad, fallback=fb)
    assert float(out["a"][0, 0]) == 7.0
    assert np.array_equal(np.asarray(out["b"]["c"]), np.asarray(x * 3))


# ---------------------------------------------------------- integration-level
@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.parametrize("schedule", _POLICIES, ids=_POLICY_IDS)
def test_zero_fault_plan_is_bit_transparent(schedule):
    """Attaching a default FaultPlan() must not change any result array:
    the injector threads through dispatch/arrival but never fires."""
    base = _runner(schedule).run(rounds=_ROUNDS)
    faulted = _runner(schedule, fault_plan=FaultPlan()).run(rounds=_ROUNDS)
    for a, b in zip(_result_arrays(base), _result_arrays(faulted)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.parametrize("schedule", _POLICIES, ids=_POLICY_IDS)
def test_degradation_smoke_all_policies(schedule):
    """Acceptance: >=10% dropout + one pinned NaN update — every policy
    completes, rejections are logged and billed, and the aggregated global
    PEFT stays finite."""
    plan = FaultPlan(seed=7, dropout_prob=0.3, nan_updates=((1, 2),))
    runner = _runner(schedule, fault_plan=plan)
    res = runner.run(rounds=_ROUNDS)
    assert res.rounds == _ROUNDS
    assert _finite_tree(runner.state.global_peft)
    rejected = [
        e for e in runner.scheduler.fault_log
        if e["reason"] in ("dropout", "non-finite-update")
    ]
    assert rejected, "expected at least one rejected update"
    for e in rejected:
        assert e["burned_compute_s"] >= 0.0
        if e["reason"] == "dropout":
            assert e["retry_after"] > e["time"]  # backoff scheduled


@pytest.mark.slow
@pytest.mark.chaos
def test_fault_sequence_identical_across_cohort_modes():
    """Same plan, batched vs sequential cohort execution: identical fault
    coordinates and rejection reasons, event devices identical, times equal
    to float tolerance (the cross-mode determinism contract)."""
    plan = FaultPlan(seed=7, dropout_prob=0.3, nan_updates=((1, 2),))
    sched = ScheduleConfig(policy="deadline", deadline_s=200.0, straggler="carry")
    logs, events = [], []
    for mode in ("batched", "sequential"):
        runner = _runner(sched, cohort_mode=mode, fault_plan=plan)
        runner.run(rounds=_ROUNDS)
        logs.append(runner.scheduler.fault_log)
        events.append(runner.scheduler.event_log)
    keyed = [
        [(e["round"], e["dev"], e["reason"]) for e in log] for log in logs
    ]
    assert keyed[0] == keyed[1]
    np.testing.assert_allclose(
        [e["time"] for e in logs[0]], [e["time"] for e in logs[1]], rtol=1e-9
    )
    assert [(r, d) for r, d, _ in events[0]] == [(r, d) for r, d, _ in events[1]]
    np.testing.assert_allclose(
        [t for _, _, t in events[0]], [t for _, _, t in events[1]], rtol=1e-9
    )


@pytest.mark.slow
@pytest.mark.chaos
def test_total_dropout_idle_advances_not_stalls():
    """dropout_prob=1.0: every update is rejected and every device ends up
    backing off — the deadline-aware fallback must idle-advance the virtual
    clock and keep closing rounds instead of stalling or raising."""
    # backoff far longer than a round, so within 3 rounds every device is
    # backing off simultaneously and dispatch finds nothing — the idle-
    # advance path must fire
    plan = FaultPlan(
        seed=0, dropout_prob=1.0, retry_backoff_s=5000.0, max_backoff_s=20000.0
    )
    runner = _runner(
        ScheduleConfig(policy="deadline", deadline_s=200.0, straggler="drop"),
        fault_plan=plan,
    )
    res = runner.run(rounds=_ROUNDS)
    assert res.rounds == _ROUNDS
    assert res.arrivals.sum() == 0  # nothing ever aggregated
    assert np.all(np.diff(res.cum_time_s) > 0)  # the clock kept moving
    assert _finite_tree(runner.state.global_peft)


@pytest.mark.slow
@pytest.mark.chaos
def test_kill_and_restart_under_faults(tmp_path):
    """ServerKilled fires after the checkpoint; resuming with the SAME plan
    reproduces the uninterrupted faulted run bit-for-bit (fault draws are
    stateless, so the restart replays the identical fault sequence)."""
    plan = FaultPlan(seed=7, dropout_prob=0.3, nan_updates=((1, 2),))
    sched = ScheduleConfig(policy="deadline", deadline_s=200.0, straggler="carry")
    base = _runner(sched, fault_plan=plan).run(rounds=_ROUNDS)

    killer = dataclasses.replace(plan, kill_at_rounds=(1,))
    d = str(tmp_path / "ckpt")
    runner = _runner(sched, fault_plan=killer, checkpoint_dir=d)
    with pytest.raises(ServerKilled):
        runner.run(rounds=_ROUNDS)
    resumed = _runner(sched, fault_plan=plan, checkpoint_dir=d, resume=True)
    res = resumed.run(rounds=_ROUNDS)
    for a, b in zip(_result_arrays(base), _result_arrays(res)):
        np.testing.assert_array_equal(a, b)
