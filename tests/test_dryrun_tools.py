"""Dry-run tooling: HLO collective parser, roofline math, mesh factory."""
import json
import os
import subprocess
import sys

import pytest


def test_collective_bytes_parser():
    # import without triggering the XLA_FLAGS override side effects (the
    # env var only matters before jax device init; jax is already live here)
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ar = f32[128,256] all-reduce(%x), replica_groups={}
  %ag.1 = bf16[4,1024] all-gather(%y), dimensions={1}
  %a2a = (f32[16,16], f32[16,16]) all-to-all(%p, %q)
  %cp = u32[8] collective-permute(%z), source_target_pairs={{0,1}}
  %dot = f32[128,128] dot(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 4 * 1024 * 2
    assert out["all-to-all"] == 2 * 16 * 16 * 4
    assert out["collective-permute"] == 8 * 4
    assert out["count"] == 4
    assert out["total"] == sum(
        out[k] for k in ("all-reduce", "all-gather", "all-to-all", "collective-permute", "reduce-scatter")
    )


def test_roofline_model_flops_orders():
    from benchmarks.roofline import model_flops

    # train >> prefill >> decode for the same arch
    t = model_flops("yi-6b", "train_4k")
    p = model_flops("yi-6b", "prefill_32k")
    d = model_flops("yi-6b", "decode_32k")
    assert t > p / 10 and p > d  # train_4k ~1M tokens; prefill 1M; decode 128
    # dense 6B: train flops ~ 4*N*tokens within 2x
    n = 6e9
    tokens = 256 * 4096
    assert 0.3 < t / (4 * n * tokens) < 3


def test_roofline_row_dominant_term():
    from benchmarks.roofline import roofline_row

    rec = {
        "arch": "yi-6b",
        "shape": "decode_32k",
        "mesh": "16x16",
        "chips": 256,
        "stld_mode": "off",
        "flops": 1e14,  # large enough to beat the analytic memory-lb term
        "bytes_accessed": 1e9,
        "collectives": {"total": 1e6},
        "memory": {"peak_bytes": 2**30, "argument_bytes": 2**30},
    }
    row = roofline_row(rec)
    assert row["dominant"] == "compute"
    assert row["t_compute_s"] == pytest.approx(1e14 / 197e12)
    assert row["t_memory_s"] > 0  # analytic lower bound engaged


def test_mesh_factory_shapes():
    """make_production_mesh needs 512 host devices -> subprocess."""
    code = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512';"
        "from repro.launch.mesh import make_production_mesh;"
        "m1 = make_production_mesh();"
        "assert dict(m1.shape) == {'data': 16, 'model': 16}, m1.shape;"
        "m2 = make_production_mesh(multi_pod=True);"
        "assert dict(m2.shape) == {'pod': 2, 'data': 16, 'model': 16}, m2.shape;"
        "print('ok')"
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0 and "ok" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_dryrun_artifacts_if_present():
    """When the sweep has run, every artifact must be ok or a sanctioned skip."""
    d = "results/dryrun"
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("dry-run sweep not executed in this environment")
    from repro.configs import LONG_CONTEXT_SKIPS

    bad = []
    for name in os.listdir(d):
        with open(os.path.join(d, name)) as f:
            rec = json.load(f)
        if rec.get("ok"):
            continue
        if rec.get("skipped") and rec["arch"] in LONG_CONTEXT_SKIPS:
            continue
        bad.append(name)
    assert not bad, f"failed dry-run cells: {bad}"
