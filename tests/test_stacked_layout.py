"""Stacked-native parameter layout: converters, traced-program guarantees,
checkpoint back-compat, and donation safety.

The acceptance contract of the stacked-layout refactor:

* every registered config family round-trips ``stack_params`` /
  ``unstack_params`` exactly (or is honestly heterogeneous and stays a
  list),
* no ``jnp.stack``/concatenate of base-layer params appears inside any
  traced training program on the smoke config (the list layout provably
  does contain one — the test would catch a regression in either
  direction),
* the client call signature shrinks from O(L·k) to O(k) leaves,
* a pre-refactor list-layout ``save_state`` checkpoint loads into the
  stacked runner and resumes bit-identically,
* donated round buffers are never reused by the engine.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.analysis import jaxpr_contracts
from repro.configs import (
    ARCH_IDS,
    FederatedConfig,
    PEFTConfig,
    STLDConfig,
    TrainConfig,
    get_config,
)
from repro.core import peft as peft_lib
from repro.data import make_task
from repro.federated.client import make_client_fns
from repro.models import stacking
from repro.models.registry import init_params
from repro.optim import adamw_init

_CFG = get_config("qwen3-1.7b", smoke=True).replace(
    num_layers=4, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
    vocab_size=128, dtype="float32",
)
_FED = FederatedConfig(num_devices=5, devices_per_round=3, local_steps=2, batch_size=8)
_TRAIN = TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2)
_TASK = make_task(num_examples=256, vocab_size=128, seed=0)


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------- round-trip
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_layout_round_trip_all_families(arch, key):
    """For every registered config family: the stacked and list layouts hold
    identical values, and stack/unstack round-trips exactly."""
    cfg = get_config(arch, smoke=True)
    auto = init_params(key, cfg)
    listed = init_params(key, cfg, layout="list")

    def layer_trees(params):
        if cfg.is_encoder_decoder:
            return {
                "enc": params["encoder"]["layers"],
                "dec": params["decoder"]["layers"],
            }
        return {"lm": params["layers"]}

    for name, (a, l) in (
        (k, (layer_trees(auto)[k], layer_trees(listed)[k]))
        for k in layer_trees(auto)
    ):
        if stacking.is_stacked(a):
            _tree_equal(stacking.unstack_params(a), l)
            _tree_equal(stacking.stack_params(l), a)
            _tree_equal(
                stacking.stack_params(stacking.unstack_params(a)), a
            )
        else:
            # honestly heterogeneous: auto must equal the list layout and
            # refuse to stack
            _tree_equal(a, l)
            assert not stacking.is_stackable(l)
            with pytest.raises(ValueError):
                stacking.stack_params(l)


@pytest.mark.parametrize("method", ["lora", "adapter", "bitfit"])
def test_peft_layout_round_trip(method, key):
    pcfg = PEFTConfig(method=method, lora_rank=2, adapter_dim=4)
    stacked = peft_lib.init_peft(key, _CFG, pcfg)
    listed = peft_lib.init_peft(key, _CFG, pcfg, layout="list")
    assert stacking.is_stacked(stacked)
    _tree_equal(stacking.unstack_params(stacked), listed)
    _tree_equal(stacking.stack_params(listed), stacked)


# --------------------------------------------------- traced-program contract
def _client_setup(layout, stld_mode="cond"):
    pcfg = PEFTConfig(method="lora", lora_rank=2)
    scfg = STLDConfig(mode=stld_mode, mean_rate=0.5, gather_bucket=1)
    fns = make_client_fns(_CFG, pcfg, scfg, _TRAIN, stack_mode="scan", donate=False)
    key = jax.random.PRNGKey(0)
    base = init_params(key, _CFG, layout=layout)
    peft = peft_lib.init_peft(key, _CFG, pcfg, layout=layout)
    batches = {
        "tokens": jnp.zeros((2, 4, 8), dtype=jnp.int32),
        "targets": jnp.zeros((2, 4, 8), dtype=jnp.int32),
        "mask": jnp.ones((2, 4, 8), dtype=jnp.float32),
    }
    args = (
        base, peft, adamw_init(peft), batches,
        jnp.asarray(0.5, jnp.float32), key, jnp.asarray(0, jnp.int32),
    )
    return fns, base, args


def _stacking_concats(fns, base, args, num_active=None):
    """Concatenate eqns in the traced local_round whose output shape matches
    a stacked base-layer leaf (i.e. trace-time layer stacking).  The walker
    lives in ``repro.analysis`` and is shared with the contract checker."""
    target_shapes = jaxpr_contracts.stacked_leaf_shapes(base["layers"])
    jaxpr = jax.make_jaxpr(
        lambda *a: fns.local_round(*a, num_active=num_active)
    )(*args)
    return jaxpr_contracts.stacking_concats(jaxpr, target_shapes)


@pytest.mark.parametrize("stld_mode,num_active", [("cond", None), ("gather", 2)])
def test_no_traced_base_stack_in_stacked_layout(stld_mode, num_active):
    """Acceptance: no jnp.stack of base-layer params inside the traced
    training program when the stacked layout is used — and the list layout
    DOES contain one, proving the test can detect a regression."""
    fns, base, args = _client_setup("stacked", stld_mode)
    assert _stacking_concats(fns, base, args, num_active) == []
    fns, base, args = _client_setup("list", stld_mode)
    assert len(_stacking_concats(fns, base, args, num_active)) > 0


def test_signature_leaf_count_reduction():
    """O(L·k) -> O(k): the stacked client signature must not scale with L."""
    _, base_s, args_s = _client_setup("stacked")
    _, base_l, args_l = _client_setup("list")
    leaves_s = len(jax.tree.leaves(args_s))
    leaves_l = len(jax.tree.leaves(args_l))
    assert leaves_l > leaves_s * 2
    # base layers alone: k leaves vs L·k
    n_stacked = len(jax.tree.leaves(base_s["layers"]))
    n_list = len(jax.tree.leaves(base_l["layers"]))
    assert n_list == n_stacked * _CFG.num_layers


# ----------------------------------------------------- checkpoint back-compat
def _experiment_kwargs(tmp, **kw):
    return dict(
        cfg=_CFG, peft_cfg=PEFTConfig(method="lora", lora_rank=2),
        stld_cfg=STLDConfig(mode="cond", mean_rate=0.5),
        fed_cfg=_FED, train_cfg=_TRAIN, seed=3, task=_TASK, **kw,
    )


def test_list_layout_checkpoint_resumes_bit_identical(tmp_path):
    """A pre-refactor (list-layout) ``save_state`` checkpoint loads into the
    stacked-native runner and resumes exactly like an uninterrupted run."""
    from repro.checkpoint import ckpt as ckpt_lib

    full_dir = str(tmp_path / "full")
    runner = api.build(
        "droppeft", **_experiment_kwargs(
            tmp_path, checkpoint_dir=full_dir, checkpoint_every=2,
        )
    )
    res_full = runner.run(rounds=4)

    # replay the first 2 rounds, then rewrite the checkpoint's PEFT trees
    # into the legacy list layout (exactly what a pre-refactor run saved)
    half_dir = str(tmp_path / "half")
    r1 = api.build(
        "droppeft", **_experiment_kwargs(
            tmp_path, checkpoint_dir=half_dir, checkpoint_every=2,
        )
    )
    r1.run(rounds=2)
    latest = ckpt_lib.latest_state_dir(half_dir)
    arrays, meta = ckpt_lib.load_state(latest)
    num_layers = _CFG.num_layers

    def to_list(tree):
        return [
            jax.tree.map(lambda x: np.asarray(x)[l], tree) for l in range(num_layers)
        ]

    arrays["global_peft"] = to_list(arrays["global_peft"])
    arrays["device_peft"] = {
        d: to_list(t) for d, t in arrays["device_peft"].items()
    }
    ckpt_lib.save_state(half_dir, meta["round_index"], arrays, meta)

    r2 = api.build(
        "droppeft", **_experiment_kwargs(
            tmp_path, checkpoint_dir=half_dir, checkpoint_every=2, resume=True,
        )
    )
    assert r2.state.round_index == 2
    assert stacking.is_stacked(r2.state.global_peft)  # converted on load
    res_resumed = r2.run(rounds=4)
    for f in ("cum_time_s", "accuracy", "loss", "rates", "traffic_mb"):
        np.testing.assert_array_equal(
            getattr(res_full, f), getattr(res_resumed, f), err_msg=f
        )
    assert res_full.final_accuracy == res_resumed.final_accuracy


# -------------------------------------------------------------- donation
def test_donation_safe_round_trip():
    """With donation force-enabled, repeated engine-style rounds never reuse
    a donated buffer (fresh stacks each round) and reproduce the
    non-donating programs' results.

    NOTE: XLA CPU ignores donation, so on the CPU-only CI runner this test
    exercises the donate_argnums plumbing and call discipline but cannot
    observe actual buffer invalidation — the ``is_deleted`` assertions below
    only engage on GPU/TPU, where donation is real."""
    pcfg = PEFTConfig(method="lora", lora_rank=2)
    scfg = STLDConfig(mode="cond", mean_rate=0.5)
    fns_d = make_client_fns(_CFG, pcfg, scfg, _TRAIN, stack_mode="scan", donate=True)
    fns_n = make_client_fns(_CFG, pcfg, scfg, _TRAIN, stack_mode="scan", donate=False)
    key = jax.random.PRNGKey(0)
    base = init_params(key, _CFG)
    peft = peft_lib.init_peft(key, _CFG, pcfg)
    n = 3
    batch_stack = {
        "tokens": jnp.zeros((n, 2, 4, 8), dtype=jnp.int32),
        "targets": jnp.zeros((n, 2, 4, 8), dtype=jnp.int32),
        "mask": jnp.ones((n, 2, 4, 8), dtype=jnp.float32),
    }
    rates = jnp.full((n,), 0.3, dtype=jnp.float32)
    rngs = jnp.stack(jax.random.split(key, n))
    gsteps = jnp.arange(n, dtype=jnp.int32)
    val = (
        jnp.zeros((n, 4, 8), dtype=jnp.int32),
        jnp.zeros((n, 4), dtype=jnp.int32),
        jnp.ones((n, 4), dtype=jnp.float32),
        jnp.arange(4),
    )

    def stack_fresh():
        return jax.tree.map(lambda *xs: jnp.stack(xs), *([peft] * n))

    ref = None
    for _ in range(2):  # a fresh stack per round: donate never sees a reuse
        donated = stack_fresh()
        out_d = fns_d.cohort_round_eval(
            base, donated, batch_stack, rates, rngs, gsteps, *val
        )
        out_n = fns_n.cohort_round_eval(
            base, stack_fresh(), batch_stack, rates, rngs, gsteps, *val
        )
        for a, b in zip(jax.tree.leaves(out_d), jax.tree.leaves(out_n)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        if jax.default_backend() != "cpu":
            # where XLA implements donation the input buffer must be gone
            assert all(x.is_deleted() for x in jax.tree.leaves(donated))
        ref = out_d
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(ref))

    # local_round donates its AdamW state: fresh state per call is safe
    batches = {k: v[0] for k, v in batch_stack.items()}
    out1 = fns_d.local_round(
        base, peft, adamw_init(peft), batches, rates[0], rngs[0], gsteps[0]
    )
    out2 = fns_n.local_round(
        base, peft, adamw_init(peft), batches, rates[0], rngs[0], gsteps[0]
    )
    for a, b in zip(jax.tree.leaves(out1), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- stacked select ops
def test_select_layers_matches_list_selection(key):
    from repro.federated import server as server_lib

    pcfg = PEFTConfig(method="lora", lora_rank=2)
    g = peft_lib.init_peft(key, _CFG, pcfg)
    o = jax.tree.map(lambda x: x + 1.0, g)
    mask = np.array([True, False, True, False])
    sel = server_lib.select_layers(mask, g, o)
    gl, ol = stacking.unstack_params(g), stacking.unstack_params(o)
    expect = [gl[l] if mask[l] else ol[l] for l in range(_CFG.num_layers)]
    _tree_equal(stacking.unstack_params(sel), expect)
