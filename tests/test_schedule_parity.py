"""The virtual-clock scheduler contains the barrier loop as a special case.

``schedule="sync"`` must reproduce the PR-2 runner's ``SimResult`` arrays
bit-for-bit (the frozen legacy simulator remains the transitively-checked
anchor via ``test_method_parity.py``), and ``schedule="deadline"`` with an
infinite budget, ``straggler="drop"`` and ``staleness_alpha=0`` must match
``sync`` exactly — same pattern, same ``np.array_equal`` strictness, for
every registered method.  The deadline path runs the full event machinery
(dispatch-time cost accounting, priority-queue arrival pops, arrival-set
aggregation), so exact equality here proves the async engine's bookkeeping
does not perturb the math, only the schedule.
"""
import math
import warnings

import numpy as np
import pytest

from _legacy_simulator import FederatedSimulator as LegacySimulator
from repro import api
from repro.configs import FederatedConfig, PEFTConfig, STLDConfig, TrainConfig, get_config
from repro.data import make_task
from repro.federated.scheduler import ScheduleConfig

_CFG = get_config("qwen3-1.7b", smoke=True).replace(
    num_layers=4, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
    vocab_size=128, dtype="float32",
)
_FED = FederatedConfig(num_devices=5, devices_per_round=3, local_steps=2, batch_size=8)
_TRAIN = TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2)
_ROUNDS = 3
_FIELDS = (
    "cum_time_s", "accuracy", "loss", "rates",
    "active_fraction", "traffic_mb", "energy_j", "memory_gb", "arrivals",
)

_TASK = make_task(num_examples=256, vocab_size=128, seed=0)

# the deadline config that must be indistinguishable from the barrier loop
_SYNC_AS_DEADLINE = ScheduleConfig(
    policy="deadline", deadline_s=math.inf, straggler="drop", staleness_alpha=0.0
)


def _peft_cfg(method):
    kind = "adapter" if method in ("fedadapter", "fedadaopt") else "lora"
    return PEFTConfig(method=kind, lora_rank=2, adapter_dim=4)


def _run(method, schedule):
    return api.experiment(
        method,
        cfg=_CFG,
        peft_cfg=_peft_cfg(method),
        stld_cfg=STLDConfig(mode="cond", mean_rate=0.5, gather_bucket=1),
        fed_cfg=_FED,
        train_cfg=_TRAIN,
        seed=3,
        task=_TASK,
        rounds=_ROUNDS,
        schedule=schedule,
    )


def _assert_results_equal(res_a, res_b):
    assert res_a.rounds == res_b.rounds
    for f in _FIELDS:
        a, b = getattr(res_a, f), getattr(res_b, f)
        if a is None or b is None:  # legacy SimResult has no arrivals column
            continue
        np.testing.assert_array_equal(a, b, err_msg=f)
    assert res_a.final_accuracy == res_b.final_accuracy


# droppeft (batched, full method incl. bandit + PTLS) and fedhetlora
# (sequential, rank heterogeneity) cover both execution paths in the fast
# tier; the remaining methods ride in the slow tier
_FAST = ("droppeft", "fedhetlora")


@pytest.mark.parametrize(
    "method",
    [
        m if m in _FAST else pytest.param(m, marks=pytest.mark.slow)
        for m in api.list_methods()
    ],
)
def test_deadline_inf_is_bitwise_sync(method):
    """deadline=inf, drop, alpha=0 == sync, for every registered method."""
    res_sync = _run(method, "sync")
    res_deadline = _run(method, _SYNC_AS_DEADLINE)
    _assert_results_equal(res_sync, res_deadline)


@pytest.mark.parametrize("method", _FAST)
def test_sync_schedule_is_bitwise_legacy(method):
    """schedule="sync" reproduces the frozen pre-refactor simulator exactly
    (direct anchor; the full method sweep lives in test_method_parity)."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = LegacySimulator(
            _CFG, _peft_cfg(method), STLDConfig(mode="cond", mean_rate=0.5, gather_bucket=1),
            _FED, _TRAIN, strategy=method, seed=3, task=_TASK,
        )
    res_old = legacy.run(rounds=_ROUNDS)
    res_new = _run(method, "sync")
    assert res_old.rounds == res_new.rounds
    for f in _FIELDS:
        if not hasattr(res_old, f):
            continue
        np.testing.assert_array_equal(getattr(res_old, f), getattr(res_new, f), err_msg=f)
    assert res_old.final_accuracy == res_new.final_accuracy


@pytest.mark.slow
def test_deadline_inf_is_bitwise_sync_gather_mode():
    """Gather-mode STLD exercises the static-count cohort partitioning
    through the event-driven dispatch path too."""
    kw = dict(
        cfg=_CFG, peft_cfg=_peft_cfg("droppeft"),
        stld_cfg=STLDConfig(mode="gather", mean_rate=0.5, gather_bucket=1),
        fed_cfg=_FED, train_cfg=_TRAIN, seed=5, task=_TASK, rounds=_ROUNDS,
    )
    res_sync = api.experiment("droppeft", schedule="sync", **kw)
    res_deadline = api.experiment("droppeft", schedule=_SYNC_AS_DEADLINE, **kw)
    _assert_results_equal(res_sync, res_deadline)


def test_finite_deadline_drops_stragglers_and_speeds_the_clock():
    """A deadline between the fastest and slowest device cuts arrivals below
    the cohort size and advances the virtual clock by at most the deadline
    per round."""
    profiles = ["tx2", "nx", "agx", "tx2", "nx"]
    kw = dict(
        cfg=_CFG, peft_cfg=_peft_cfg("droppeft"),
        stld_cfg=STLDConfig(mode="cond", mean_rate=0.5, gather_bucket=1),
        fed_cfg=_FED, train_cfg=_TRAIN, seed=3, task=_TASK, rounds=_ROUNDS,
        device_profile=profiles, cost_model=get_config("qwen3-1.7b"),
    )
    res_sync = api.experiment("droppeft", schedule="sync", **kw)
    # pick a budget below the sync per-round time so tx2 stragglers miss it
    round_times = np.diff(np.concatenate([[0.0], res_sync.cum_time_s]))
    deadline = float(round_times.min()) * 0.5
    res_dl = api.experiment(
        "droppeft", schedule="deadline", deadline_s=deadline, **kw
    )
    assert res_dl.arrivals.min() >= 1
    assert res_dl.arrivals.max() <= _FED.devices_per_round
    assert (res_dl.arrivals < _FED.devices_per_round).any(), (
        "expected at least one round to cut a straggler"
    )
    # each round advances by <= deadline (up to the first-arrival guarantee)
    dl_rounds = np.diff(np.concatenate([[0.0], res_dl.cum_time_s]))
    assert res_dl.cum_time_s[-1] < res_sync.cum_time_s[-1]
    assert (dl_rounds <= max(deadline, dl_rounds.min()) + 1e-9).all()


def test_async_buffer_aggregates_k_and_discounts_staleness():
    """FedBuff semantics: every row aggregates exactly K arrivals, the
    virtual clock is non-decreasing, and sub-cohort buffers close faster
    than the barrier."""
    profiles = ["tx2", "nx", "agx", "tx2", "nx"]
    kw = dict(
        cfg=_CFG, peft_cfg=_peft_cfg("droppeft"),
        stld_cfg=STLDConfig(mode="cond", mean_rate=0.5, gather_bucket=1),
        fed_cfg=_FED, train_cfg=_TRAIN, seed=3, task=_TASK, rounds=_ROUNDS,
        device_profile=profiles, cost_model=get_config("qwen3-1.7b"),
    )
    res_sync = api.experiment("droppeft", schedule="sync", **kw)
    res_async = api.experiment(
        "droppeft", schedule="async-buffer", buffer_size=2, staleness_alpha=0.5, **kw
    )
    assert (res_async.arrivals == 2).all()
    assert (np.diff(res_async.cum_time_s) >= 0).all()
    assert res_async.cum_time_s[-1] < res_sync.cum_time_s[-1]


def test_checkpointing_allowed_for_in_flight_policies(tmp_path):
    """Durable rounds lifted the old refusal: async-buffer builds with a
    checkpoint_dir and writes a snapshot (bit-exact resume is covered by
    tests/test_durable_rounds.py)."""
    runner = api.build(
        "droppeft", cfg=_CFG, peft_cfg=_peft_cfg("droppeft"),
        fed_cfg=_FED, train_cfg=_TRAIN, task=_TASK,
        schedule="async-buffer", checkpoint_dir=str(tmp_path),
    )
    runner.run(rounds=1)
    assert any(tmp_path.iterdir()), "no run-state snapshot written"
