"""``hypothesis`` when installed, a seeded ``parametrize`` fallback otherwise.

The tier-1 container is offline and ships without the ``hypothesis`` wheel,
which used to kill three modules at *collection*.  Property-test modules now
import ``given`` / ``settings`` / ``st`` from here:

* with hypothesis present these are the real objects — full shrinking,
  fuzzing, the works;
* without it, ``@given`` expands each strategy into a deterministic, seeded
  example list (boundary values first, then uniform draws keyed on the test
  name) and registers it via ``pytest.mark.parametrize``, so the same
  properties still run everywhere as ordinary parametrized cases.

Only the strategy combinators the suite actually uses are shimmed
(``floats``, ``integers``, ``sampled_from``, ``booleans``).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random
    import zlib

    import pytest

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        """A boundary-example list plus a seeded uniform sampler."""

        def __init__(self, boundary, sample):
            self.boundary = list(boundary)
            self.sample = sample

    class _StrategiesShim:
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                [min_value, max_value], lambda r: r.uniform(min_value, max_value)
            )

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                [min_value, max_value], lambda r: r.randint(min_value, max_value)
            )

        @staticmethod
        def sampled_from(elements):
            xs = list(elements)
            return _Strategy(xs, lambda r: r.choice(xs))

        @staticmethod
        def booleans():
            return _Strategy([False, True], lambda r: r.random() < 0.5)

    st = _StrategiesShim()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        """Records ``max_examples`` for the ``@given`` shim; other knobs
        (deadline, ...) are hypothesis-only and ignored."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        names = list(strats)

        def deco(fn):
            n = max(1, getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            cases = []
            width = max(len(s.boundary) for s in strats.values())
            for j in range(width):  # boundary grid first
                cases.append(
                    tuple(s.boundary[j % len(s.boundary)] for s in strats.values())
                )
            while len(cases) < n + width:  # then seeded uniform draws
                cases.append(tuple(s.sample(rng) for s in strats.values()))
            unique = list(dict.fromkeys(cases))[:n]
            if len(names) == 1:  # parametrize wants scalars, not 1-tuples
                unique = [c[0] for c in unique]
            return pytest.mark.parametrize(",".join(names), unique)(fn)

        return deco
