"""Virtual-clock scheduler unit + determinism contract.

Event orderings must be a pure function of (seed, profiles, policy):
identical across repeated runs and across ``cohort_mode="batched"`` /
``"sequential"`` execution — the event heap is keyed ``(finish_time,
device_id)``, never dict order, and completion times come from the
deterministic ``SystemModel``, not host wall-clock.
"""
import math

import numpy as np
import pytest

from repro import api
from repro.configs import FederatedConfig, PEFTConfig, STLDConfig, TrainConfig, get_config
from repro.data import make_task
from repro.federated.scheduler import (
    ScheduleConfig,
    feasible_rate_floor,
    resolve_schedule,
)
from repro.federated.system_model import SystemModel

_CFG = get_config("qwen3-1.7b", smoke=True).replace(
    num_layers=4, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
    vocab_size=128, dtype="float32",
)
_FED = FederatedConfig(num_devices=6, devices_per_round=4, local_steps=2, batch_size=8)
_TRAIN = TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2)
_TASK = make_task(num_examples=256, vocab_size=128, seed=0)
_PROFILES = ["tx2", "nx", "agx", "tx2", "nx", "agx"]
_ROUNDS = 3


def _runner(schedule, *, cohort_mode="batched", seed=3, method="droppeft"):
    return api.build(
        method,
        cfg=_CFG,
        peft_cfg=PEFTConfig(method="lora", lora_rank=2),
        stld_cfg=STLDConfig(mode="cond", mean_rate=0.5, gather_bucket=1),
        fed_cfg=_FED,
        train_cfg=_TRAIN,
        seed=seed,
        task=_TASK,
        cohort_mode=cohort_mode,
        schedule=schedule,
        device_profile=_PROFILES,
        cost_model=get_config("qwen3-1.7b"),
    )


def _log_devices(log):
    return [(r, dev) for r, dev, _t in log]


def _log_times(log):
    return np.asarray([t for _r, _dev, t in log])


@pytest.mark.parametrize(
    "schedule",
    [
        "sync",
        ScheduleConfig(policy="deadline", deadline_s=1e4, straggler="drop"),
        ScheduleConfig(policy="deadline", deadline_s=1e4, straggler="carry",
                       staleness_alpha=0.5),
        ScheduleConfig(policy="async-buffer", buffer_size=2, staleness_alpha=0.5),
    ],
    ids=["sync", "deadline-drop", "deadline-carry", "async"],
)
def test_identical_seeds_identical_events(schedule):
    """Two runs with the same seed produce identical event logs, virtual
    clocks, and result arrays."""
    logs, results = [], []
    for _ in range(2):
        runner = _runner(schedule)
        results.append(runner.run(rounds=_ROUNDS))
        logs.append(list(runner.scheduler.event_log))
    assert _log_devices(logs[0]) == _log_devices(logs[1])
    np.testing.assert_array_equal(_log_times(logs[0]), _log_times(logs[1]))
    np.testing.assert_array_equal(results[0].cum_time_s, results[1].cum_time_s)
    np.testing.assert_array_equal(results[0].accuracy, results[1].accuracy)
    np.testing.assert_array_equal(results[0].arrivals, results[1].arrivals)


@pytest.mark.parametrize(
    "schedule",
    [
        "sync",
        ScheduleConfig(policy="deadline", deadline_s=1e4, straggler="drop"),
        ScheduleConfig(policy="async-buffer", buffer_size=2, staleness_alpha=0.5),
    ],
    ids=["sync", "deadline", "async"],
)
def test_batched_and_sequential_modes_order_events_identically(schedule):
    """The event *ordering* (which device finishes when, relative to the
    others) must not depend on the execution engine's dispatch strategy.
    Completion times are SystemModel outputs of (profile, bandwidth draw,
    measured active fraction); the two engine modes consume identical RNG
    streams and produce numerically matching active fractions, so the
    device order is exactly equal and the clocks agree to float tolerance."""
    runner_b = _runner(schedule, cohort_mode="batched")
    res_b = runner_b.run(rounds=_ROUNDS)
    runner_s = _runner(schedule, cohort_mode="sequential")
    res_s = runner_s.run(rounds=_ROUNDS)
    assert _log_devices(runner_b.scheduler.event_log) == _log_devices(
        runner_s.scheduler.event_log
    )
    np.testing.assert_allclose(
        _log_times(runner_b.scheduler.event_log),
        _log_times(runner_s.scheduler.event_log),
        rtol=1e-9,
    )
    np.testing.assert_array_equal(res_b.arrivals, res_s.arrivals)
    np.testing.assert_allclose(res_b.cum_time_s, res_s.cum_time_s, rtol=1e-9)


def test_event_heap_tie_breaks_by_device_id():
    """Equal finish times pop in device-id order (never dict/hash order)."""
    import heapq

    heap = []
    for dev in (5, 1, 3, 2, 4):
        heapq.heappush(heap, (1.0, dev))
    heapq.heappush(heap, (0.5, 9))
    popped = [heapq.heappop(heap)[1] for _ in range(len(heap))]
    assert popped == [9, 1, 2, 3, 4, 5]


def test_virtual_time_tracks_cum_time_in_sync():
    runner = _runner("sync")
    runner.run(rounds=2)
    assert runner.state.virtual_time == runner.state.cum_time
    assert runner.state.server_version == 2


def test_carry_keeps_straggler_updates_in_flight():
    """With a tight deadline and carry, cut-off updates stay in flight and
    land later (or are still pending at the end) — never silently lost."""
    sync_runner = _runner("sync")
    sync = sync_runner.run(rounds=_ROUNDS)
    round_times = np.diff(np.concatenate([[0.0], sync.cum_time_s]))
    deadline = float(round_times.min()) * 0.5
    runner = _runner(
        ScheduleConfig(policy="deadline", deadline_s=deadline, straggler="carry",
                       staleness_alpha=0.5)
    )
    res = runner.run(rounds=_ROUNDS)
    assert res.arrivals.min() >= 1  # a round never closes before the first arrival
    # same seed => the carry run's round-0 cohort is the sync run's round-0
    # cohort (where every member arrives), so the round-0 cut set is exact
    cohort0 = {dev for r, dev, _t in sync_runner.scheduler.event_log if r == 0}
    on_time0 = {dev for r, dev, _t in runner.scheduler.event_log if r == 0}
    cut0 = cohort0 - on_time0
    assert cut0, (
        f"a deadline of half the fastest sync round must cut at least one "
        f"round-0 straggler on the mixed tx2/nx/agx cohort (cohort {cohort0})"
    )
    assert len(on_time0) == int(res.arrivals[0])
    # carried updates are never lost: every cut device either landed in a
    # later round or is still in flight when the run ends
    landed_late = {
        dev for r, dev, _t in runner.scheduler.event_log if r > 0
    }
    unaccounted = cut0 - landed_late - set(runner.scheduler.in_flight)
    assert not unaccounted, f"carried updates vanished for devices {unaccounted}"


def test_resolve_schedule_overrides():
    cfg = resolve_schedule("deadline", deadline_s=5.0, staleness_alpha=0.25)
    assert cfg.policy == "deadline"
    assert cfg.deadline_s == 5.0
    assert cfg.staleness_alpha == 0.25
    assert resolve_schedule(None).policy == "sync"
    base = ScheduleConfig(policy="async-buffer", buffer_size=3)
    assert resolve_schedule(base) is base
    assert resolve_schedule(base, buffer_size=5).buffer_size == 5
    with pytest.raises(ValueError):
        ScheduleConfig(policy="bogus")
    with pytest.raises(ValueError):
        ScheduleConfig(deadline_s=0.0)


def test_resolve_schedule_infers_policy_and_rejects_dead_options():
    """Options without an explicit policy infer one; options that would be
    silently dead under sync raise instead of being ignored."""
    assert resolve_schedule(None, deadline_s=30.0).policy == "deadline"
    assert resolve_schedule(None, straggler="carry").policy == "deadline"
    assert resolve_schedule(None, buffer_size=2).policy == "async-buffer"
    with pytest.raises(ValueError, match="sync"):
        resolve_schedule("sync", deadline_s=30.0)
    with pytest.raises(ValueError, match="staleness_alpha"):
        resolve_schedule(None, staleness_alpha=0.5)


def test_staleness_weights_formula():
    from repro.federated import server as server_lib

    w = server_lib.staleness_weights(np.array([0, 1, 3]), alpha=1.0)
    expect = np.array([1.0, 0.5, 0.25])
    np.testing.assert_allclose(w, expect / expect.sum())
    np.testing.assert_allclose(
        server_lib.staleness_weights(np.array([0, 7]), alpha=0.0), [0.5, 0.5]
    )


def test_weighted_fedavg_matches_manual():
    import jax.numpy as jnp

    from repro.federated import server as server_lib

    trees = [{"a": jnp.array([1.0, 2.0])}, {"a": jnp.array([3.0, 6.0])}]
    out = server_lib.weighted_fedavg(trees, np.array([0.75, 0.25]))
    np.testing.assert_allclose(out["a"], [1.5, 3.0])
    uniform = server_lib.weighted_fedavg(trees, np.array([0.5, 0.5]))
    np.testing.assert_allclose(uniform["a"], server_lib.fedavg(trees)["a"])


def test_hetlora_extra_weights_compose():
    """extra_weights (the scheduler's staleness discount) multiplies the
    rank shares; None keeps pure rank weighting."""
    import jax.numpy as jnp

    from repro.federated import server as server_lib

    def layer(val):
        return {"attn": {"q": {"a": jnp.full((3, 2), val), "b": jnp.full((2, 3), val)}}}

    c0, c1 = [layer(1.0)], [layer(3.0)]
    out = server_lib.hetlora_aggregate(
        [c0, c1], [2, 2], 2, extra_weights=np.array([1.0, 0.0])
    )
    np.testing.assert_allclose(out[0]["attn"]["q"]["a"], c0[0]["attn"]["q"]["a"])
    out2 = server_lib.hetlora_aggregate([c0, c1], [2, 2], 2)
    np.testing.assert_allclose(out2[0]["attn"]["q"]["a"], np.full((3, 2), 2.0))


@pytest.mark.parametrize("method", ["fedlora", "fedhetlora"])
def test_nonptls_methods_run_with_staleness(method):
    """The staleness-weighted merge paths that are NOT PTLS — base.merge's
    weighted_fedavg and hetlora_aggregate(extra_weights=...) — actually
    execute under an alpha>0 async schedule."""
    runner = _runner(
        ScheduleConfig(policy="async-buffer", buffer_size=2, staleness_alpha=0.5),
        method=method,
        cohort_mode="auto",
    )
    res = runner.run(rounds=2)
    assert len(res.accuracy) == 2
    assert np.all(np.isfinite(res.accuracy))
    assert np.all(np.diff(res.cum_time_s) > 0)


def test_legacy_configure_round_signature():
    """A pre-scheduler subclass overriding configure_round(state) still runs
    under sync and deadline-drop (no kwargs needed) and gets an actionable
    TypeError under policies that require size=/exclude=."""
    from repro.federated.algorithms.base import FederatedAlgorithm

    class Legacy(FederatedAlgorithm):
        def configure_round(self, state):
            return super().configure_round(state)

    res = _runner(
        ScheduleConfig(policy="deadline", deadline_s=1e4, straggler="drop"),
        method=Legacy(),
    ).run(rounds=1)
    assert len(res.accuracy) == 1
    with pytest.raises(TypeError, match="configure_round"):
        _runner(
            ScheduleConfig(policy="async-buffer", buffer_size=2),
            method=Legacy(),
        ).run(rounds=1)


def test_feasible_rate_floor_monotone_in_deadline():
    """Tighter deadlines demand more dropout; an infinite budget demands
    none; an impossible budget caps at the max grid rate."""
    system = SystemModel(get_config("qwen3-1.7b"), PEFTConfig(method="lora"))
    grid = (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)
    kw = dict(rate_grid=grid, batch=16, seq=128, local_steps=4)
    profiles = ["tx2", "nx", "agx"]
    assert feasible_rate_floor(system, profiles, math.inf, **kw) == 0.0
    assert feasible_rate_floor(system, profiles, 1e-9, **kw) == max(grid)
    t_full = float(
        system.cohort_round_cost(
            devices=["tx2"], bandwidth_mbps=40.0, batch=16, seq=128,
            local_steps=4, peft=True, active_fraction=1.0, share_fraction=1.0,
        ).total_time_s[0]
    )
    floors = [
        feasible_rate_floor(system, profiles, d, **kw)
        for d in (t_full * 2, t_full * 0.7, t_full * 0.4)
    ]
    assert floors[0] == 0.0
    assert floors == sorted(floors), f"floor must tighten with the deadline: {floors}"
    assert floors[-1] > 0.0
