"""Paper Figs. 11-12: per-device energy and total network traffic."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, run_sim


def run(quick: bool = False):
    rounds = 4 if quick else 8
    res = {}
    for strategy, peft in (("fedlora", "lora"), ("droppeft", "lora"),
                            ("fedadapter", "adapter"), ("droppeft", "adapter")):
        r = run_sim(strategy, rounds=rounds, peft=peft, seed=3)
        res[f"{strategy}({peft})"] = r
        emit(
            f"fig11_12/{strategy}({peft})",
            float(np.sum(r.energy_j)),
            f"energy_kj={np.sum(r.energy_j)/1e3:.1f};traffic_mb={np.sum(r.traffic_mb):.0f}",
        )
    # DropPEFT saves energy (fewer FLOPs per round) and traffic (PTLS upload)
    assert np.sum(res["droppeft(lora)"].energy_j) < np.sum(res["fedlora(lora)"].energy_j)
    assert np.sum(res["droppeft(lora)"].traffic_mb) < np.sum(res["fedlora(lora)"].traffic_mb)
