"""Multi-tenant serving bench: batched heterogeneous-LoRA decode vs
per-request adapter switching.

The tentpole claim of the serving subsystem: admitting every tenant's
request into ONE fused decode batch (per-row adapters via the segmented
gather kernel, continuous batching) beats the naive server that processes
requests one at a time, switching the active adapter between requests.
Both sides run the *same* compiled pooled decode program — the baseline is
simply batch=1 with sequential requests — so the measured gap is the
batching win, not a kernel difference.

Also asserted: adapter hot-swap into a recycled pool slot causes ZERO
steady-state recompiles (pool shapes static, slot index + contents traced).

Measurement discipline per the container profile: interleaved min-of-N
trials and an explicit margin before the claim is asserted.  Outputs: CSV
rows, one JSON summary line, and ``BENCH_serve.json`` for CI artifacts.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, sim_model_cfg
from repro.analysis.recompile_guard import CompilationCounter
from repro.configs import PEFTConfig
from repro.core import peft as peft_lib
from repro.launch.steps import make_serve_step
from repro.models.registry import init_params
from repro.serving.adapters import AdapterPoolCache, AdapterRegistry
from repro.serving.batcher import ContinuousBatcher, Request

MARGIN = 0.05
CLAIM_SPEEDUP = 2.0  # batched multi-adapter >= 2x per-request switching
_BATCH = 4
_TENANTS = 6  # > n_slots so steady state exercises hot-swap eviction
_PROMPT = 4


def _registry(cfg, key):
    reg = AdapterRegistry()
    for i in range(_TENANTS):
        rank = (4, 8)[i % 2]  # hetlora mixed ranks share one pool
        pcfg = PEFTConfig(method="lora", lora_rank=rank, lora_targets=("q", "v"))
        tree = peft_lib.init_peft(jax.random.fold_in(key, 100 + i), cfg, pcfg)
        reg.register(f"tenant{i}", tree)
    return reg


def _submit(batcher, cfg, key, gen_len, tenants):
    for j, t in enumerate(tenants):
        prompt = jax.random.randint(
            jax.random.fold_in(key, j), (_PROMPT,), 0, cfg.vocab_size
        ).tolist()
        batcher.submit(
            Request(prompt=prompt, adapter=f"tenant{t}", max_new_tokens=gen_len, uid=j)
        )


def run(quick: bool = False):
    gen_len = 8 if quick else 32
    trials = 2 if quick else 5
    cfg = sim_model_cfg()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    serve = make_serve_step(cfg, stack_mode="scan")
    reg = _registry(cfg, key)
    max_len = _PROMPT + gen_len + 1

    def make_batcher(batch):
        pool = AdapterPoolCache(reg, n_slots=_BATCH)
        return ContinuousBatcher(
            serve, params, cfg, pool,
            batch=batch, max_len=max_len, cache_dtype=jnp.float32,
        )

    # one batcher per mode, reused across trials: jit caches live on the
    # batcher's step closure, so fresh batchers would re-pay compilation
    batched = make_batcher(_BATCH)
    switching = make_batcher(1)

    def run_batched(tenants):
        _submit(batched, cfg, key, gen_len, tenants)
        return batched.run()

    def run_switching(tenants):
        # per-request serving: one request at a time, adapter switched
        # (pool slot swap) between requests
        done = []
        for t in tenants:
            _submit(switching, cfg, key, gen_len, [t])
            done += switching.run()
        return done

    tenant_sets = [[0, 1, 2, 3], [2, 3, 4, 5]]  # second set forces hot-swaps
    # warm both compiled programs (and the slot-write program)
    n0 = len(run_batched(tenant_sets[0]))
    n1 = len(run_switching(tenant_sets[0]))
    assert n0 == len(tenant_sets[0]) and n1 == len(tenant_sets[0])

    # steady state: rotating the tenant mix (adapter hot-swap into recycled
    # slots) must not trigger a single compile
    with CompilationCounter() as cc:
        out = run_batched(tenant_sets[1])
    steady_recompiles = cc.count
    assert len(out) == len(tenant_sets[1])

    best = {"batched": float("inf"), "switching": float("inf")}
    tokens = {}
    for trial in range(trials):
        tenants = tenant_sets[trial % len(tenant_sets)]
        for name, fn in (("batched", run_batched), ("switching", run_switching)):
            t0 = time.perf_counter()
            done = fn(tenants)
            dt = time.perf_counter() - t0
            tokens[name] = sum(len(c.tokens) for c in done)
            best[name] = min(best[name], dt / max(tokens[name], 1))

    tps = {name: 1.0 / best[name] for name in best}
    for name in tps:
        emit(
            f"serve/{name}_tok_s", best[name] * 1e6,
            f"tok_s={tps[name]:.1f};batch={_BATCH};gen={gen_len};trials={trials}",
        )
    speedup = tps["batched"] / tps["switching"]
    claim_ok = speedup >= CLAIM_SPEEDUP * (1.0 - MARGIN)
    emit("serve/batched_speedup", 0.0, f"x{speedup:.2f};claim>={CLAIM_SPEEDUP}")
    emit("serve/steady_state_recompiles", 0.0, f"n={steady_recompiles}")

    summary = {
        "bench": "serve",
        "batch": _BATCH,
        "tenants": _TENANTS,
        "gen_len": gen_len,
        "batched_tok_s": round(tps["batched"], 2),
        "switching_tok_s": round(tps["switching"], 2),
        "speedup_min_of_trials": round(speedup, 3),
        "margin": MARGIN,
        "claim_batched_2x": claim_ok,
        "steady_state_recompiles": steady_recompiles,
        "pool_swaps": batched.pool.swaps,
        "trials": trials,
    }
    print(json.dumps(summary))
    out_path = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)

    assert steady_recompiles == 0, (
        f"adapter hot-swap must reuse the compiled serving step; "
        f"counted {steady_recompiles} steady-state compiles"
    )
    # the speedup claim is wall-clock and flakes on shared CI runners:
    # always recorded in BENCH_serve.json, asserted only in strict mode
    # (the default locally; on CI it downgrades to a warning unless
    # BENCH_SERVE_STRICT=1 opts back in)
    strict = os.environ.get(
        "BENCH_SERVE_STRICT", "0" if os.environ.get("CI") else "1"
    ) == "1"
    if not claim_ok:
        msg = (
            f"batched multi-adapter decode should be >= {CLAIM_SPEEDUP}x "
            f"per-request switching; got x{speedup:.2f}"
        )
        if strict:
            raise AssertionError(msg)
        print(f"# WARNING (non-strict): {msg}", file=sys.stderr)


if __name__ == "__main__":
    run()
