"""Scheduling-policy bench: sync vs deadline vs async on a mixed cohort.

The virtual-clock scheduler's claim is the paper's claim: on a
heterogeneous cohort (tx2 is ~16x slower than agx at the 1.7B cost scale),
closing rounds at a deadline or aggregating FedBuff-style buffers reaches a
target accuracy in less *virtual* time than the barrier-synchronous loop,
because the barrier pins every round to the slowest straggler.

Protocol per the repo bench convention (container profile: min-of-trials +
explicit margin):

* the smoke training model (8 layers) runs the actual federated
  optimization; the 1.7B cost config drives the virtual clock;
* the device mix is pinned to interleaved tx2/nx/agx so every cohort
  contains stragglers;
* each policy runs over several seeds; time-to-accuracy (sustained, on the
  virtual clock) is taken as the min over seeds;
* the target accuracy is the worst run's sustained maximum, so TTA is
  defined for every run and no policy is scored on rounds it never reached;
* the asserted claim is *deadline/async TTA <= sync TTA within MARGIN*;
  the measured speedups are reported, not asserted;
* a fourth column, ``async_compressed``, runs the same async schedule with
  int8+top-k EF uplinks: it must bill strictly less traffic than ``async``
  and reach the shared target within MARGIN of it.

Outputs: CSV rows (stdout), one JSON summary line, and
``BENCH_schedule.json`` for the CI artifact trail.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import cost_model_cfg, emit, fed_cfg, sim_model_cfg, train_cfg
from repro import api
from repro.configs import PEFTConfig
from repro.federated.scheduler import ScheduleConfig
from repro.federated.system_model import SystemModel

MARGIN = 0.05  # deadline/async must be <= sync TTA within 5%

_DEVICES = 8
_COHORT = 4
_PROFILES = ["tx2", "nx", "agx", "tx2", "nx", "agx", "tx2", "nx"]


def _deadline_budget() -> float:
    """A round budget that admits nx/agx at moderate dropout but cuts a
    full-depth tx2 straggler: 1.5x the predicted nx time at rate 0.5."""
    system = SystemModel(cost_model_cfg(), PEFTConfig(method="lora"))
    nx = system.cohort_round_cost(
        devices=["nx"], bandwidth_mbps=40.0, batch=16, seq=32,
        local_steps=4, peft=True, active_fraction=0.5, share_fraction=1.0,
    )
    return 1.5 * float(nx.total_time_s[0])


def _run(schedule, *, rounds, seed, compression=None):
    return api.experiment(
        "droppeft",
        cfg=sim_model_cfg(),
        peft_cfg=PEFTConfig(method="lora", lora_rank=4, adapter_dim=8),
        fed_cfg=fed_cfg(rounds=rounds, devices=_DEVICES, cohort=_COHORT),
        train_cfg=train_cfg(),
        cost_model=cost_model_cfg(),
        device_profile=_PROFILES,
        schedule=schedule,
        compression=compression,
        seed=seed,
        rounds=rounds,
    )


def _sustained_max(res) -> float:
    """Highest accuracy the run holds to the end (suffix minimum's max)."""
    suffix_min = np.minimum.accumulate(res.accuracy[::-1])[::-1]
    return float(suffix_min.max())


def run(quick: bool = False):
    rounds = 6 if quick else 10
    seeds = (0,) if quick else (0, 1)
    deadline = _deadline_budget()
    async_sched = ScheduleConfig(
        policy="async-buffer", buffer_size=max(1, _COHORT // 2),
        staleness_alpha=0.5,
    )
    # policy name -> (schedule, uplink compression); async_compressed is the
    # same event-driven loop with int8+top-k EF uplinks, so its column
    # isolates the comm-time saving at matched aggregation semantics
    policies = {
        "sync": ("sync", None),
        "deadline": (
            ScheduleConfig(policy="deadline", deadline_s=deadline, straggler="drop"),
            None,
        ),
        "async": (async_sched, None),
        "async_compressed": (async_sched, "int8+topk"),
    }

    results = {
        name: [_run(sched, rounds=rounds, seed=s, compression=comp) for s in seeds]
        for name, (sched, comp) in policies.items()
    }

    # target every run can reach: the worst run's sustained maximum
    target = min(_sustained_max(r) for rs in results.values() for r in rs)
    tta = {}
    for name, rs in results.items():
        per_seed = [r.time_to_accuracy(target, sustained=True) for r in rs]
        assert all(t is not None for t in per_seed), (
            f"{name}: no run reached the shared target {target:.3f}"
        )
        tta[name] = min(per_seed)  # min-of-trials

    traffic = {
        name: float(np.mean([r.traffic_mb.sum() for r in rs]))
        for name, rs in results.items()
    }
    for name, rs in results.items():
        virt = float(np.mean([r.cum_time_s[-1] for r in rs]))
        arr = float(np.mean([r.arrivals.mean() for r in rs]))
        emit(
            f"schedule/{name}",
            tta[name] * 1e6,
            f"tta_s={tta[name]:.1f};virtual_end_s={virt:.1f};"
            f"traffic_mb={traffic[name]:.2f};"
            f"mean_arrivals={arr:.2f};rounds={rounds};seeds={len(seeds)}",
        )
    speedup_deadline = tta["sync"] / tta["deadline"]
    speedup_async = tta["sync"] / tta["async"]
    speedup_compressed = tta["async"] / tta["async_compressed"]
    emit("schedule/speedup_deadline", 0.0, f"x{speedup_deadline:.2f};margin={MARGIN}")
    emit("schedule/speedup_async", 0.0, f"x{speedup_async:.2f};margin={MARGIN}")
    emit(
        "schedule/speedup_compressed_vs_async", 0.0,
        f"x{speedup_compressed:.2f};margin={MARGIN}",
    )

    summary = {
        "bench": "schedule",
        "devices": _DEVICES,
        "cohort": _COHORT,
        "profiles": _PROFILES,
        "rounds": rounds,
        "seeds": list(seeds),
        "deadline_s": round(deadline, 2),
        "target_accuracy": round(target, 4),
        "tta_s": {k: round(v, 2) for k, v in tta.items()},
        "traffic_mb": {k: round(v, 4) for k, v in traffic.items()},
        "compression": {"async_compressed": "int8+topk"},
        "speedup_deadline_min_of_trials": round(speedup_deadline, 3),
        "speedup_async_min_of_trials": round(speedup_async, 3),
        "speedup_compressed_vs_async_min_of_trials": round(speedup_compressed, 3),
        "margin": MARGIN,
        "claim_deadline_not_slower": speedup_deadline >= 1.0 - MARGIN,
        "claim_async_not_slower": speedup_async >= 1.0 - MARGIN,
        "claim_compressed_less_traffic": traffic["async_compressed"] < traffic["async"],
        "claim_compressed_not_slower": speedup_compressed >= 1.0 - MARGIN,
    }
    print(json.dumps(summary))
    out_path = os.environ.get("BENCH_SCHEDULE_JSON", "BENCH_schedule.json")
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)

    # the asserted claim: event-driven scheduling reaches the shared target
    # in no more virtual time than the barrier loop, within the margin
    assert speedup_deadline >= 1.0 - MARGIN, (
        f"deadline TTA slower than sync beyond the {MARGIN:.0%} margin: "
        f"{tta['deadline']:.1f}s vs {tta['sync']:.1f}s (x{speedup_deadline:.2f})"
    )
    assert speedup_async >= 1.0 - MARGIN, (
        f"async TTA slower than sync beyond the {MARGIN:.0%} margin: "
        f"{tta['async']:.1f}s vs {tta['sync']:.1f}s (x{speedup_async:.2f})"
    )
    # compressed uplinks must actually shrink the wire, and must not cost
    # accuracy-time beyond the margin (same target, same async schedule)
    assert traffic["async_compressed"] < traffic["async"], (
        f"compressed uplinks did not reduce traffic: "
        f"{traffic['async_compressed']:.2f}MB vs {traffic['async']:.2f}MB"
    )
    assert speedup_compressed >= 1.0 - MARGIN, (
        f"compressed-async TTA slower than async beyond the {MARGIN:.0%} "
        f"margin: {tta['async_compressed']:.1f}s vs {tta['async']:.1f}s "
        f"(x{speedup_compressed:.2f})"
    )


if __name__ == "__main__":
    run()
