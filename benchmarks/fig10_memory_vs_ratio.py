"""Paper Fig. 10: peak memory vs dropout ratio (qwen3-1.7b scale, NX device).

Checks the ~linear memory scaling with the active fraction and the paper's
40-67% reduction band at ratios 0.5-0.7.
"""
from __future__ import annotations

from benchmarks.common import cost_model_cfg, emit
from repro.configs import PEFTConfig
from repro.federated.system_model import SystemModel


def run(quick: bool = False):
    cfg = cost_model_cfg()
    sm = SystemModel(cfg, PEFTConfig(method="lora", lora_rank=8))
    base = sm.memory_breakdown(batch=16, seq=256, peft=True, active_fraction=1.0).total_gb
    for ratio in (0.0, 0.2, 0.4, 0.6, 0.8):
        m = sm.memory_breakdown(batch=16, seq=256, peft=True, active_fraction=1.0 - ratio)
        emit(
            f"fig10/ratio_{ratio}",
            m.total_gb * 1000,
            f"total_gb={m.total_gb:.2f};saving={1 - m.total_gb/base:.2f}",
        )
    m06 = sm.memory_breakdown(batch=16, seq=256, peft=True, active_fraction=0.4).total_gb
    assert 0.40 < 1 - m06 / base < 0.75, "paper band: >50% saving at ratio 0.6"
