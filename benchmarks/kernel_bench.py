"""Kernel micro-bench: us/call for the Pallas hot spots vs their XLA refs.

On this CPU container the Pallas kernels run in interpret mode (python —
timings are NOT meaningful for TPU); the XLA-path timings plus the analytic
FLOP counts are the portable signal, and both are reported.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, timeit
from repro.kernels import ops


def run(quick: bool = False):
    key = jax.random.PRNGKey(0)
    b, h, s, d = 1, 4, 256, 64
    q = jax.random.normal(key, (b, h, s, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, d))
    flops_attn = 4 * b * h * s * s // 2 * d
    us = timeit(lambda: ops.flash_attention(q, k, v, impl="xla"))
    emit("kernels/attention_xla_ref", us, f"gflops={flops_attn/us/1e3:.1f};shape=b{b}h{h}s{s}d{d}")

    r = 0.5 * jax.random.normal(key, (1, 128, 4, 64))
    import jax.numpy as jnp

    logw = jnp.clip(-jnp.exp(jax.random.normal(key, (1, 128, 4, 64))), -4.0, -1e-4)
    u = 0.1 * jax.random.normal(key, (4, 64))
    us = timeit(lambda: ops.wkv6(r, r, r, logw, u, impl="xla"))
    emit("kernels/wkv6_xla_ref", us, "shape=b1s128h4k64")

    dt = jax.nn.softplus(jax.random.normal(key, (1, 128, 128)))
    bm = jax.random.normal(key, (1, 128, 16))
    a = -jnp.exp(jax.random.normal(key, (128, 16)))
    dv = jnp.ones((128,))
    us = timeit(lambda: ops.mamba_scan(dt, dt, bm, bm, a, dv, impl="xla"))
    emit("kernels/mamba_scan_xla_ref", us, "shape=b1s128d128n16")

    x = jax.random.normal(key, (256, 512))
    w = jax.random.normal(key, (512, 512))
    la = jax.random.normal(key, (512, 8))
    lb = jax.random.normal(key, (8, 512))
    us = timeit(lambda: ops.lora_matmul(x, w, la, lb, impl="xla"))
    flops = 2 * 256 * 512 * 512
    emit("kernels/lora_matmul_xla_ref", us, f"gflops={flops/us/1e3:.1f}")

    if not quick:
        # interpret-mode correctness spot checks double as bench entries
        us = timeit(lambda: ops.flash_attention(q[:, :, :64], k[:, :, :64], v[:, :, :64], block_q=32, block_k=32), iters=1, warmup=1)
        emit("kernels/attention_pallas_interpret", us, "correctness-path; not TPU timing")
