"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads ``results/dryrun/*.json`` and derives, per (arch x shape x mesh):

    compute    = HLO_FLOPs / (chips x 197e12)           [s]
    memory     = HLO_bytes / (chips x 819e9)            [s]
    collective = collective_bytes / (chips x 50e9)      [s]

Conventions: jax's ``cost_analysis()`` on an SPMD-partitioned module reports
*per-device* FLOPs/bytes, so terms divide by 1 (already per chip); the
collective bytes sum the output shapes of the partitioned program's
collectives (per-device traffic across all links of that device).

MODEL_FLOPS = 6*N_active*D tokens (train: x3 fwd+bwd is folded into the 6;
decode/prefill use 2*N_active per token) + exact attention term; the ratio
MODEL_FLOPS / (HLO_FLOPs x chips) flags remat/redundancy/dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful-FLOPs for the whole step (all chips)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    counts = cfg.param_counts()
    n_active = counts["active"]
    hd = cfg.resolved_head_dim

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 4 * n_active * tokens  # fwd(2N) + PEFT bwd(~2N)
        attn_ctx = shape.seq_len / 2
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2 * n_active * tokens
        attn_ctx = shape.seq_len / 2
    else:  # decode: one token against a seq_len cache
        tokens = shape.global_batch * 1
        base = 2 * n_active * tokens
        attn_ctx = shape.seq_len

    # attention score+value FLOPs over the causal context
    n_attn_layers = sum(
        1 for l in range(cfg.num_layers)
        if cfg.family != "ssm" and cfg.is_attention_layer(l)
    )
    window = cfg.sliding_window
    ctx = min(attn_ctx, window) if window else attn_ctx
    attn = 4 * tokens * ctx * cfg.num_heads * hd * n_attn_layers
    if shape.kind == "train":
        attn *= 2  # backward recomputes/differentiates attention
    return base + attn


def memory_lower_bound(arch: str, shape_name: str, chips: int, tp: int = 16) -> float:
    """Analytic minimum HBM traffic per device per step [bytes].

    ``cost_analysis()['bytes accessed']`` on the CPU backend counts every
    unfused op's operands — a large over-estimate of TPU traffic after
    fusion.  The floor is: every live parameter read once per pass, each
    activation written+read once, plus KV-cache/logits IO.  The truth lies
    in [lb, ub]; the dominant-term call uses the lb (achievable on TPU).
    """
    from repro.federated.system_model import SystemModel

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    sm = SystemModel(cfg)
    dtype_b = 2
    params_dev = cfg.param_counts()["total"] * 4 / tp  # fp32 master weights
    data_shards = chips // tp
    act_tok = sm.activation_bytes_per_token()

    if shape.kind == "train":
        tokens_dev = shape.global_batch * shape.seq_len / data_shards
        traffic = 3 * params_dev + 2 * act_tok * tokens_dev
        traffic += 3 * tokens_dev * cfg.vocab_size / tp * dtype_b  # logits io
    elif shape.kind == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / data_shards
        traffic = params_dev + act_tok * tokens_dev / 4  # stream, no bwd save
        hd = cfg.resolved_head_dim
        traffic += tokens_dev * 2 * cfg.num_kv_heads * hd * cfg.num_layers * dtype_b
    else:  # decode: read all params + the KV cache once per token
        b_dev = max(shape.global_batch // data_shards, 1)
        hd = cfg.resolved_head_dim
        window = cfg.sliding_window
        ctx = min(shape.seq_len, window) if window else shape.seq_len
        n_attn = sum(
            1 for l in range(cfg.num_layers)
            if cfg.family != "ssm" and cfg.is_attention_layer(l)
        )
        cache = b_dev * ctx * 2 * cfg.num_kv_heads * hd * n_attn * dtype_b
        if shape.global_batch == 1:
            cache /= data_shards  # sequence-sharded cache (long_500k)
        traffic = params_dev + cache
    return float(traffic)


def load_records(dryrun_dir: str = "results/dryrun", tag_filter: str = ""):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag_filter and r.get("tags", "") != tag_filter:
            continue
        recs.append(r)
    return recs


def roofline_row(rec: dict) -> dict:
    chips = rec.get("chips", 256)
    flops_dev = rec["flops"]                   # per-device (see module doc)
    bytes_dev = rec["bytes_accessed"]
    coll_dev = rec["collectives"]["total"]
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory_ub = bytes_dev / HBM_BW
    t_memory_lb = memory_lower_bound(rec["arch"], rec["shape"], chips) / HBM_BW
    t_coll = coll_dev / ICI_BW_PER_LINK
    # dominant term uses the achievable (post-fusion) memory estimate
    dom = max(
        (("compute", t_compute), ("memory", t_memory_lb), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(rec["arch"], rec["shape"])
    ratio = mf / max(flops_dev * chips, 1.0)
    if rec.get("stack_mode", "unroll") != "unroll":
        # scan/group lowering: cost_analysis counts the loop body once, so
        # the useful-ratio is not meaningful (multi-pod cells prove sharding,
        # not cost accounting — DESIGN.md §8)
        ratio = float("nan")
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "stld": rec.get("stld_mode", "off"),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory_lb,
        "t_memory_ub_s": t_memory_ub,
        "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_total": flops_dev * chips,
        "useful_ratio": ratio,
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
        "resident_gib": rec["memory"]["argument_bytes"] / 2**30,
    }


def run(quick: bool = False):
    recs = [r for r in load_records() if r.get("ok")]
    if not recs:
        print("roofline/no_dryrun_artifacts,0.0,run launch/dryrun first")
        return
    for rec in recs:
        row = roofline_row(rec)
        print(
            f"roofline/{row['arch']}/{row['shape']}/{row['mesh']}"
            f"{'/stld-' + row['stld'] if row['stld'] != 'off' else ''},"
            f"{max(row['t_compute_s'], row['t_memory_s'], row['t_collective_s'])*1e6:.1f},"
            f"compute={row['t_compute_s']:.2e};memory={row['t_memory_s']:.2e};"
            f"memory_ub={row['t_memory_ub_s']:.2e};"
            f"collective={row['t_collective_s']:.2e};dominant={row['dominant']};"
            f"useful={row['useful_ratio']:.2f};peak_gib={row['peak_gib']:.2f}"
        )


def markdown_table(dryrun_dir: str = "results/dryrun") -> str:
    rows = [roofline_row(r) for r in load_records(dryrun_dir) if r.get("ok")]
    out = [
        "| arch | shape | mesh | stld | compute (s) | memory lb (s) | memory ub (s) | collective (s) | dominant | useful ratio | resident GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['stld']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} | {r['t_memory_ub_s']:.2e} "
            f"| {r['t_collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} | {r['resident_gib']:.2f} |"
        )
    return "\n".join(out)
