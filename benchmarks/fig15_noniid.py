"""Paper Fig. 15: final accuracy under varying non-IIDness (Dirichlet alpha);
PTLS (DropPEFT) vs no-PTLS (DropPEFT-b3)."""
from __future__ import annotations

from benchmarks.common import emit, run_sim


def run(quick: bool = False):
    alphas = (1.0, 0.1) if quick else (10.0, 1.0, 0.1)
    rounds = 5 if quick else 12
    degradation = {}
    final_accs = {}
    for strategy in ("droppeft", "droppeft_b3"):
        accs = {}
        for alpha in alphas:
            res = run_sim(strategy, rounds=rounds, alpha=alpha, seed=5)
            accs[alpha] = res.final_accuracy
            emit(f"fig15/{strategy}/alpha_{alpha}", 0.0, f"final_acc={res.final_accuracy:.3f}")
        degradation[strategy] = accs[max(alphas)] - accs[min(alphas)]
        final_accs[strategy] = accs
    emit(
        "fig15/ptls_robustness",
        0.0,
        f"degradation_ptls={degradation['droppeft']:.3f};"
        f"degradation_noptls={degradation['droppeft_b3']:.3f}",
    )
    # At smoke scale, per-device evaluation makes extreme skew EASIER (local
    # test sets narrow), so absolute degradation can invert sign; the paper's
    # claim maps to the relative statement: PTLS >= no-PTLS at high skew.
    lo = min(alphas)
    emit(
        "fig15/high_skew_ptls_vs_noptls",
        0.0,
        f"ptls={final_accs['droppeft'][lo]:.3f};noptls={final_accs['droppeft_b3'][lo]:.3f}",
    )
