"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2,table1]

Prints ``name,us_per_call,derived`` CSV rows (stdout), one per measurement.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    cohort_bench,
    faults_bench,
    round_bench,
    schedule_bench,
    fig2_breakdown,
    fig3_memory,
    fig6_dropout_sweep,
    fig10_memory_vs_ratio,
    fig11_12_energy_traffic,
    fig13_14_ablations,
    fig15_noniid,
    kernel_bench,
    roofline,
    serve_bench,
    table1_overhead,
    table3_time_to_accuracy,
)

BENCHES = {
    "cohort": cohort_bench.run,
    "faults": faults_bench.run,
    "round": round_bench.run,
    "schedule": schedule_bench.run,
    "serve": serve_bench.run,
    "table1": table1_overhead.run,
    "fig2": fig2_breakdown.run,
    "fig3": fig3_memory.run,
    "table3": table3_time_to_accuracy.run,
    "fig6": fig6_dropout_sweep.run,
    "fig10": fig10_memory_vs_ratio.run,
    "fig11_12": fig11_12_energy_traffic.run,
    "fig13_14": fig13_14_ablations.run,
    "fig15": fig15_noniid.run,
    "kernels": kernel_bench.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="reduced rounds/sweeps")
    ap.add_argument("--only", default="", help="comma-separated bench names")
    args = ap.parse_args()

    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(BENCHES)
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        t0 = time.time()
        try:
            BENCHES[name](quick=args.quick)
            print(f"# {name}: done in {time.time()-t0:.1f}s", file=sys.stderr)
        except AssertionError as e:
            failures.append(name)
            print(f"{name}/CLAIM_VIOLATION,0.0,{e}")
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
