"""Paper Fig. 2: computation-time breakdown (forward vs backward).

Measured on the CPU smoke model: FFT (grads w.r.t. everything), PEFT
(grads w.r.t. LoRA only — backward shrinks, forward doesn't), and
DropPEFT/STLD at rate 0.5 (both passes shrink).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, sim_model_cfg, timeit
from repro.configs import PEFTConfig
from repro.core import peft as peft_lib
from repro.models import init_params, model_apply
from repro.models.losses import softmax_xent


def run(quick: bool = False):
    cfg = sim_model_cfg().replace(num_layers=8)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    peft = peft_lib.init_peft(key, cfg, PEFTConfig(method="lora", lora_rank=4))
    batch = jax.random.randint(key, (8, 64), 0, cfg.vocab_size)
    drops_none = jnp.zeros((8,), dtype=bool)
    drops_half = jnp.array([False, True] * 4)

    @jax.jit
    def fwd(p, pf, drops):
        logits, aux, _ = model_apply(p, cfg, {"tokens": batch}, peft=pf, drops=drops, stack_mode="scan")
        loss, _ = softmax_xent(logits[:, :-1], batch[:, 1:])
        return loss

    @jax.jit
    def fwd_bwd_fft(p, pf, drops):
        return jax.grad(lambda pp: fwd(pp, pf, drops))(p)

    @jax.jit
    def fwd_bwd_peft(p, pf, drops):
        return jax.grad(lambda x: fwd(p, x, drops))(pf)

    t_fwd = timeit(fwd, params, peft, drops_none)
    t_fft = timeit(fwd_bwd_fft, params, peft, drops_none)
    t_peft = timeit(fwd_bwd_peft, params, peft, drops_none)
    t_drop_f = timeit(fwd, params, peft, drops_half)
    t_drop = timeit(fwd_bwd_peft, params, peft, drops_half)

    emit("fig2/forward", t_fwd)
    emit("fig2/fft_total", t_fft, f"bwd={t_fft - t_fwd:.0f}us;fwd_share={t_fwd/t_fft:.2f}")
    emit("fig2/peft_total", t_peft, f"bwd={t_peft - t_fwd:.0f}us;fwd_share={t_fwd/t_peft:.2f}")
    emit("fig2/droppeft_total", t_drop, f"fwd={t_drop_f:.0f}us")

    # paper claims: PEFT shortens backward but forward is untouched ->
    # forward share grows; STLD cuts BOTH.
    assert t_peft < t_fft
    assert t_drop < 0.9 * t_peft, f"STLD should cut total: {t_drop:.0f} vs {t_peft:.0f}"
