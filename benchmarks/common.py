"""Shared benchmark helpers."""
from __future__ import annotations

import time


from repro.configs import FederatedConfig, PEFTConfig, TrainConfig, get_config

# the smoke model every simulation benchmark trains (CPU-sized), and the
# full-size config used for system-model cost accounting (paper scale)
SIM_ARCH = "qwen3-1.7b"


def sim_model_cfg():
    # 8 layers: deep enough for layer dropout to behave as in the paper's
    # 12-24-layer models (at 4 layers, dropping half the depth is degenerate)
    return get_config(SIM_ARCH, smoke=True).replace(
        num_layers=8, d_model=64, d_ff=128, num_heads=4, num_kv_heads=2,
        vocab_size=512, dtype="float32",
    )


def cost_model_cfg():
    return get_config(SIM_ARCH)  # 1.7B — closest assigned arch to the paper's 1.5B


def fed_cfg(rounds=8, devices=8, cohort=4, alpha=1.0, **kw):
    return FederatedConfig(
        num_devices=devices, devices_per_round=cohort, local_steps=4,
        batch_size=16, rounds=rounds, dirichlet_alpha=alpha,
        # moderate-rate grid + short exploit phases: the bandit must converge
        # within the short smoke sessions (paper runs 100 rounds)
        rate_grid=(0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7),
        explore_interval=4,
        **kw,
    )


def train_cfg():
    return TrainConfig(learning_rate=5e-3, total_steps=400, warmup_steps=5)


# explicit "not passed" sentinel: fixed_rate=0.0 is a legitimate sweep point
# (zero dropout) and must not fall back to the bandit or a 0.5 default
_UNSET = object()


def run_sim(strategy, *, rounds=8, peft="lora", stld_mode="cond", fixed_rate=_UNSET,
            distribution="incremental", alpha=1.0, seed=0, schedule=None,
            device_profile=None):
    from repro import api

    return api.experiment(
        strategy,
        cfg=sim_model_cfg(),
        peft_cfg=PEFTConfig(method=peft, lora_rank=4, adapter_dim=8),
        stld_mode=stld_mode,
        distribution=distribution,
        fixed_rate=None if fixed_rate is _UNSET else fixed_rate,
        fed_cfg=fed_cfg(rounds=rounds, alpha=alpha),
        train_cfg=train_cfg(),
        cost_model=cost_model_cfg(),
        seed=seed,
        schedule=schedule,
        device_profile=device_profile,
        rounds=rounds,
    )


def timeit(fn, *args, iters=3, warmup=1):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    try:
        import jax

        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6  # us


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
