"""Per-round dispatch bench: stacked-native vs list-layout client programs.

The stacked-native parameter layout changes two things about every round's
``cohort_round_eval`` dispatch on exactly the same math:

* the traced program no longer calls ``jnp.stack`` over the per-layer base
  weights (list layout materializes a second full copy of the frozen base
  inside each compiled step), and
* the call signature shrinks from O(L·k) pytree leaves to O(k), so the
  per-dispatch arg flattening cost stops scaling with depth.

Both layouts are timed through the *same* jit'd ``ClientFns`` factory on the
smoke cohort workload (8 devices, 8 layers, 1 step x batch 4 x seq 8 — the
dispatch-bound regime from ``cohort_bench``).  Measurement discipline per
the container profile: interleaved min-of-N trials (background load is
additive noise that min filters out) and an explicit margin before any
claim is asserted.  The asserted claim is *stacked-native >= list-layout*
(i.e. at least parity within ``MARGIN``); the measured speedup is reported,
not asserted, because at smoke scale this 2-core container is
op-overhead-bound and the margin must not overclaim.

Outputs: CSV rows (stdout, like every bench), one JSON summary line, and a
``BENCH_round.json`` file for the CI artifact trail.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, sim_model_cfg, train_cfg
from repro.configs import PEFTConfig, STLDConfig
from repro.core import peft as peft_lib
from repro.federated.client import make_client_fns
from repro.models.registry import init_params

_DEVICES = 8
_STEPS = 1
_BATCH = 4
_SEQ = 8
MARGIN = 0.05  # claim threshold: stacked >= list within 5% measurement noise


def _cohort_args(cfg, peft_tree, key):
    """Stacked-over-devices cohort inputs for ``cohort_round_eval``."""
    n = _DEVICES
    peft_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *([peft_tree] * n))
    kb, kt, kv = jax.random.split(key, 3)
    batch_stack = {
        "tokens": jax.random.randint(kb, (n, _STEPS, _BATCH, _SEQ), 0, cfg.vocab_size),
        "targets": jax.random.randint(kt, (n, _STEPS, _BATCH, _SEQ), 0, cfg.vocab_size),
        "mask": jnp.ones((n, _STEPS, _BATCH, _SEQ), dtype=jnp.float32),
    }
    rates = jnp.full((n,), 0.5, dtype=jnp.float32)
    rngs = jnp.stack(jax.random.split(key, n))
    gsteps = jnp.arange(n, dtype=jnp.int32)
    val_tokens = jax.random.randint(kv, (n, _BATCH, _SEQ), 0, cfg.vocab_size)
    val_labels = jnp.zeros((n, _BATCH), dtype=jnp.int32)
    val_valid = jnp.ones((n, _BATCH), dtype=jnp.float32)
    num_classes = jnp.arange(4)
    return (
        peft_stack, batch_stack, rates, rngs, gsteps,
        val_tokens, val_labels, val_valid, num_classes,
    )


def run(quick: bool = False):
    reps = 5 if quick else 20
    trials = 2 if quick else 5
    cfg = sim_model_cfg()
    pcfg = PEFTConfig(method="lora", lora_rank=4)
    scfg = STLDConfig(mode="cond", mean_rate=0.5)
    fns = make_client_fns(cfg, pcfg, scfg, train_cfg(), stack_mode="scan", donate=False)
    key = jax.random.PRNGKey(0)

    layouts = {}
    for layout in ("stacked", "list"):
        base = init_params(key, cfg, layout=layout)
        peft = peft_lib.init_peft(jax.random.fold_in(key, 1), cfg, pcfg, layout=layout)
        layouts[layout] = (base, _cohort_args(cfg, peft, jax.random.fold_in(key, 2)))

    # leaf-count reduction of the client call signature (O(L·k) -> O(k))
    leaves = {
        layout: len(jax.tree.leaves((base, args)))
        for layout, (base, args) in layouts.items()
    }

    # warm both compiled programs, then interleave trials; keep per-layout
    # minima (min-of-trials filters the shared container's additive noise)
    outs = {}
    for layout, (base, args) in layouts.items():
        outs[layout] = fns.cohort_round_eval(base, *args)
        jax.block_until_ready(outs[layout])
    best = {layout: float("inf") for layout in layouts}
    for _ in range(trials):
        for layout, (base, args) in layouts.items():
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(fns.cohort_round_eval(base, *args))
            best[layout] = min(best[layout], (time.perf_counter() - t0) / reps)

    # same math: the two layouts must produce matching round outputs
    # (canonicalize the list-layout PEFT output to stacked leaves first —
    # the device axis leads, so stack per-layer trees along axis 1)
    def canon(out):
        peft_out, metrics, imps, accs = out
        if isinstance(peft_out, (list, tuple)):
            peft_out = jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=1), *peft_out
            )
        return (peft_out, metrics, imps, accs)

    ls, ll = (jax.tree.leaves(canon(outs[k])) for k in ("stacked", "list"))
    parity = len(ls) == len(ll) and all(
        np.allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        for a, b in zip(ls, ll)
    )

    for layout in best:
        emit(
            f"round/dispatch_{layout}",
            best[layout] * 1e6,
            f"devices={_DEVICES};reps={reps};trials={trials};leaves={leaves[layout]}",
        )
    speedup = best["list"] / best["stacked"]
    leaf_reduction = leaves["list"] / leaves["stacked"]
    emit("round/dispatch_speedup", 0.0, f"x{speedup:.2f};margin={MARGIN}")
    emit("round/signature_leaf_reduction", 0.0, f"x{leaf_reduction:.1f}")

    summary = {
        "bench": "round",
        "devices": _DEVICES,
        "layers": cfg.num_layers,
        "dispatch_list_ms": round(best["list"] * 1e3, 3),
        "dispatch_stacked_ms": round(best["stacked"] * 1e3, 3),
        "speedup_min_of_trials": round(speedup, 3),
        "margin": MARGIN,
        "claim_stacked_not_slower": speedup >= 1.0 - MARGIN,
        "leaves_list": leaves["list"],
        "leaves_stacked": leaves["stacked"],
        "leaf_reduction": round(leaf_reduction, 1),
        "outputs_match": parity,
        "reps": reps,
        "trials": trials,
    }
    print(json.dumps(summary))
    out_path = os.environ.get("BENCH_ROUND_JSON", "BENCH_round.json")
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)

    assert parity, "stacked-native and list-layout rounds diverged"
    assert leaf_reduction >= 4.0, (
        f"stacked signature should shrink the dispatch pytree by >= 4x "
        f"(O(L·k) -> O(k)); got {leaves['list']} -> {leaves['stacked']}"
    )
    # the asserted perf claim: stacked-native is at least as fast as the
    # list layout on min-of-trials wall-clock, within the stated margin
    assert speedup >= 1.0 - MARGIN, (
        f"stacked-native round dispatch slower than list layout beyond the "
        f"{MARGIN:.0%} margin: {best['stacked']*1e3:.3f}ms vs "
        f"{best['list']*1e3:.3f}ms (x{speedup:.2f})"
    )


if __name__ == "__main__":
    run()
