"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.report > results/roofline_report.md
"""
from __future__ import annotations

from collections import defaultdict

from benchmarks.roofline import load_records, roofline_row


def dryrun_table() -> str:
    recs = load_records()
    out = [
        "| arch | shape | mesh | status | compile (s) | HLO GFLOPs/dev | HLO GB/dev | coll GB/dev | resident GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r.get("mesh", ""))):
        if r.get("tags"):
            continue  # variants appear in §Perf, not the baseline table
        if r.get("skipped"):
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP (sub-quadratic gate) | — | — | — | — | — |"
            )
            continue
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** | — | — | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | {r['compile_s']:.0f} "
            f"| {r['flops']/1e9:.1f} | {r['bytes_accessed']/1e9:.1f} "
            f"| {r['collectives']['total']/1e9:.2f} "
            f"| {r['memory']['argument_bytes']/2**30:.2f} |"
        )
    return "\n".join(out)


def main():
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline table (single-pod baselines)\n")
    rows = [r for r in load_records() if r.get("ok") and not r.get("tags") and r["mesh"] == "16x16"]
    print(markdown_table_from(rows))
    print("\n## Dominant-term summary\n")
    doms = defaultdict(list)
    for rec in rows:
        row = roofline_row(rec)
        doms[row["dominant"]].append(f"{row['arch']}/{row['shape']}")
    for k, v in sorted(doms.items()):
        print(f"- **{k}** ({len(v)}): {', '.join(v)}")


def markdown_table_from(recs):
    out = [
        "| arch | shape | stld | compute (s) | memory lb (s) | memory ub (s) | collective (s) | dominant | useful | resident GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        r = roofline_row(rec)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['stld']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} | {r['t_memory_ub_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['resident_gib']:.2f} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    main()
