"""Paper Figs. 13-14: ablations.

Fig 13 — STLD: DropPEFT vs DropPEFT-b1 (all layers always active).
Fig 14 — configurator: adaptive bandit vs fixed dropout-rate configs.
"""
from __future__ import annotations

from benchmarks.common import emit, run_sim


def run(quick: bool = False):
    rounds = 5 if quick else 12

    full = run_sim("droppeft", rounds=rounds, seed=4)
    b1 = run_sim("droppeft_b1", rounds=rounds, seed=4)
    emit("fig13/droppeft", full.cum_time_s[-1] * 1e6, f"acc={full.accuracy[-1]:.3f}")
    emit("fig13/b1_no_stld", b1.cum_time_s[-1] * 1e6, f"acc={b1.accuracy[-1]:.3f}")
    assert full.cum_time_s[-1] < b1.cum_time_s[-1], "STLD must reduce wall time"

    for rate in ((0.5,) if quick else (0.2, 0.5, 0.8)):
        fixed = run_sim("droppeft_b2", rounds=rounds, fixed_rate=rate, seed=4)
        emit(
            f"fig14/fixed_{rate}",
            fixed.cum_time_s[-1] * 1e6,
            f"acc={fixed.accuracy[-1]:.3f};time_h={fixed.cum_time_s[-1]/3600:.2f}",
        )
    emit(
        "fig14/adaptive",
        full.cum_time_s[-1] * 1e6,
        f"acc={full.accuracy[-1]:.3f};time_h={full.cum_time_s[-1]/3600:.2f}",
    )
