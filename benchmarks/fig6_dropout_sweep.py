"""Paper Fig. 6: sensitivity to (a) average dropout rate and (b) the
per-layer distribution shape at a fixed 0.5 average."""
from __future__ import annotations

from benchmarks.common import emit, run_sim


def run(quick: bool = False):
    rates = (0.3, 0.7) if quick else (0.1, 0.3, 0.5, 0.7, 0.9)
    rounds = 5 if quick else 10
    accs = {}
    for rate in rates:
        res = run_sim("droppeft_b2", rounds=rounds, fixed_rate=rate, seed=2)
        accs[rate] = res
        emit(
            f"fig6a/rate_{rate}",
            res.cum_time_s[-1] * 1e6,
            f"acc={res.accuracy[-1]:.3f};time_h={res.cum_time_s[-1]/3600:.2f}",
        )
    # extreme dropout must be cheaper per round than conservative dropout
    if 0.1 in accs and 0.9 in accs:
        assert accs[0.9].cum_time_s[-1] < accs[0.1].cum_time_s[-1]

    dists = ("uniform", "incremental") if quick else ("uniform", "incremental", "decay", "normal")
    for dist in dists:
        res = run_sim("droppeft_b2", rounds=rounds, fixed_rate=0.5, distribution=dist, seed=2)
        emit(f"fig6b/{dist}", res.cum_time_s[-1] * 1e6, f"acc={res.accuracy[-1]:.3f}")
