"""Fault-tolerance bench: graceful degradation under client dropout.

The robustness layer's claim: with seeded fault injection active — clients
dropping mid-round, one corrupted (NaN) update — every round still closes,
the aggregated PEFT state stays finite, and accuracy degrades smoothly with
the dropout probability instead of collapsing.  A zero-fault plan must be
bit-transparent: attaching ``FaultPlan()`` changes nothing.

Protocol per the repo bench convention:

* the smoke training model (8 layers) runs the actual federated
  optimization; the 1.7B cost config drives the virtual clock over the
  interleaved tx2/nx/agx mix, so dropped stragglers actually cost time;
* a deadline-drop policy takes the sweep (the policy the paper runs under
  churn); each sweep point pins one NaN update on top of i.i.d. dropout;
* the degradation curve records final accuracy, sustained max, sustained
  time-to-accuracy against the shared worst-run target, rejected-update
  counts, and burned compute per dropout probability;
* asserted claims: (1) the zero-fault plan reproduces the no-plan run
  bit-for-bit, (2) every sweep point finishes all rounds with a finite
  aggregated PEFT and finite accuracy, (3) at the highest dropout
  probability the screen actually rejected something (the faults fired).

Outputs: CSV rows (stdout), one JSON summary line, and
``BENCH_faults.json`` for the CI artifact trail.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cost_model_cfg, emit, fed_cfg, sim_model_cfg, train_cfg
from repro import api
from repro.configs import PEFTConfig
from repro.federated.faults import FaultPlan
from repro.federated.scheduler import ScheduleConfig
from repro.federated.system_model import SystemModel

_DEVICES = 8
_COHORT = 4
_PROFILES = ["tx2", "nx", "agx", "tx2", "nx", "agx", "tx2", "nx"]
_SEED = 0


def _deadline_budget() -> float:
    """Same shape as schedule_bench: admits nx/agx, cuts a tx2 straggler."""
    system = SystemModel(cost_model_cfg(), PEFTConfig(method="lora"))
    nx = system.cohort_round_cost(
        devices=["nx"], bandwidth_mbps=40.0, batch=16, seq=32,
        local_steps=4, peft=True, active_fraction=0.5, share_fraction=1.0,
    )
    return 1.5 * float(nx.total_time_s[0])


def _build(*, rounds, deadline, fault_plan):
    return api.build(
        "droppeft",
        cfg=sim_model_cfg(),
        peft_cfg=PEFTConfig(method="lora", lora_rank=4, adapter_dim=8),
        fed_cfg=fed_cfg(rounds=rounds, devices=_DEVICES, cohort=_COHORT),
        train_cfg=train_cfg(),
        cost_model=cost_model_cfg(),
        device_profile=_PROFILES,
        schedule=ScheduleConfig(
            policy="deadline", deadline_s=deadline, straggler="drop"
        ),
        seed=_SEED,
        fault_plan=fault_plan,
    )


def _sustained_max(res) -> float:
    suffix_min = np.minimum.accumulate(res.accuracy[::-1])[::-1]
    return float(suffix_min.max())


def _finite_peft(runner) -> bool:
    return all(
        bool(jnp.all(jnp.isfinite(x)))
        for x in jax.tree.leaves(runner.state.global_peft)
    )


def run(quick: bool = False):
    rounds = 5 if quick else 8
    probs = (0.0, 0.3) if quick else (0.0, 0.1, 0.3, 0.5)
    deadline = _deadline_budget()

    # bit-transparency anchor: no plan at all
    baseline = _build(rounds=rounds, deadline=deadline, fault_plan=None)
    base_res = baseline.run(rounds=rounds)

    curve = []
    results = {}
    for p in probs:
        plan = FaultPlan(
            seed=_SEED,
            dropout_prob=p,
            # pin one corrupted update so the finite screen is always on the
            # path (round 1, device 0); zero-fault point stays truly zero
            nan_updates=((1, 0),) if p > 0 else (),
        )
        runner = _build(rounds=rounds, deadline=deadline, fault_plan=plan)
        res = runner.run(rounds=rounds)
        results[p] = res
        rejected = [
            e for e in runner.scheduler.fault_log
            if e["reason"] in ("dropout", "non-finite-update")
        ]
        burned = sum(e["burned_compute_s"] for e in rejected)
        assert _finite_peft(runner), f"p={p}: aggregated PEFT went non-finite"
        assert np.all(np.isfinite(res.accuracy)), f"p={p}: non-finite accuracy"
        assert res.rounds == rounds, f"p={p}: run stalled at {res.rounds} rounds"
        curve.append({
            "dropout_prob": p,
            # repro-lint: disable=JXH002 — SimResult arrays are host numpy
            "final_accuracy": round(float(res.accuracy[-1]), 4),
            "sustained_max": round(_sustained_max(res), 4),
            # repro-lint: disable=JXH002 — SimResult arrays are host numpy
            "virtual_end_s": round(float(res.cum_time_s[-1]), 2),
            "mean_arrivals": round(float(res.arrivals.mean()), 3),
            "rejected_updates": len(rejected),
            "fault_events": len(runner.scheduler.fault_log),
            "burned_compute_s": round(float(burned), 2),
        })

    # zero-fault plan must change nothing
    zero = results[0.0]
    transparent = all(
        np.array_equal(a, b)
        for a, b in (
            (base_res.accuracy, zero.accuracy),
            (base_res.cum_time_s, zero.cum_time_s),
            (base_res.arrivals, zero.arrivals),
        )
    )

    # shared target every sweep point reached: worst run's sustained max
    # (unrounded — rounding the reported value up would make it unreachable)
    target = min(_sustained_max(results[p]) for p in probs)
    for pt, p in zip(curve, probs):
        tta = results[p].time_to_accuracy(target, sustained=True)
        assert tta is not None, f"p={p}: never sustained the shared target"
        pt["tta_s"] = round(float(tta), 2)
        emit(
            f"faults/dropout_{p:g}",
            pt["tta_s"] * 1e6,
            f"tta_s={pt['tta_s']};acc={pt['final_accuracy']};"
            f"rejected={pt['rejected_updates']};"
            f"burned_s={pt['burned_compute_s']};rounds={rounds}",
        )
    emit("faults/zero_fault_transparent", 0.0, f"bit_equal={transparent}")

    summary = {
        "bench": "faults",
        "devices": _DEVICES,
        "cohort": _COHORT,
        "profiles": _PROFILES,
        "rounds": rounds,
        "seed": _SEED,
        "policy": "deadline-drop",
        "deadline_s": round(deadline, 2),
        "target_accuracy": round(target, 4),
        "degradation_curve": curve,
        "claim_zero_fault_bit_transparent": transparent,
        "claim_all_points_finite_and_complete": True,  # asserted above
        "claim_faults_fired_at_max_dropout": curve[-1]["rejected_updates"] > 0,
    }
    print(json.dumps(summary))
    out_path = os.environ.get("BENCH_FAULTS_JSON", "BENCH_faults.json")
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)

    assert transparent, (
        "zero-fault FaultPlan() perturbed the run: attaching an empty plan "
        "must be bit-transparent"
    )
    assert curve[-1]["rejected_updates"] > 0, (
        f"dropout_prob={probs[-1]} over {rounds} rounds rejected nothing — "
        "the injector is not firing"
    )


if __name__ == "__main__":
    run()
