"""Cohort execution engine bench: sequential per-device loop vs the batched
``cohort_round`` engine.

Primary metric (asserted): wall-clock of one full cohort round through
``CohortEngine.run_cohort`` — local training + validation for the whole
8-device cohort, i.e. exactly the component the batched engine replaces.
Workload: the smoke model config (8 layers, d=64) with FedSGD-style
single-local-step rounds (1 step x batch 4 x seq 8) over small near-uniform
shards — the cross-device emulation regime the engine targets: per-device
compute is small, so the sequential loop's per-device costs (two jit
dispatches with ~100-leaf pytrees, host-side optimizer init, stacking,
blocking accuracy syncs) dominate, and one fused jit'd call over the
stacked cohort amortizes all of it.  Gather-mode STLD with a fixed rate
(DropPEFT-b2 ablation) keeps one static active-count group, so the two
modes' compiled graphs do identical math and the comparison is pure
execution strategy.  On heavy per-device workloads this 2-core CPU
container is element-throughput-bound and the two modes converge —
accelerators are where the compute side of the batched engine pays off; the
end-to-end runner comparison is reported alongside for transparency.

Like ``kernel_bench`` the portable signal is CSV rows (stdout); a JSON
summary line with the measured speedups is emitted as well so downstream
tooling can parse the claim directly.  The acceptance claim — batched >= 2x
faster than sequential for an 8-device cohort — is asserted on the engine
metric (surfaces as CLAIM_VIOLATION through benchmarks.run on failure).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import cost_model_cfg, emit, sim_model_cfg, train_cfg
from repro import api
from repro.configs import FederatedConfig, PEFTConfig, STLDConfig
from repro.data import make_task
from repro.federated.runner import ExperimentRunner

_DEVICES = 8


def _make_runner(mode: str, seed: int = 0) -> ExperimentRunner:
    fed = FederatedConfig(
        num_devices=_DEVICES,
        devices_per_round=_DEVICES,
        local_steps=1,
        batch_size=4,
        # near-uniform shards: batched evaluation pads every device's val
        # batch to the cohort max, so a skewed partition would make the
        # batched engine evaluate more rows than the sequential loop does
        dirichlet_alpha=1000.0,
    )
    return api.build(
        "droppeft_b2",  # fixed rate: one static gather group
        cfg=sim_model_cfg(),
        peft_cfg=PEFTConfig(method="lora", lora_rank=4, adapter_dim=8),
        stld_cfg=STLDConfig(mode="gather", mean_rate=0.5),
        fed_cfg=fed,
        train_cfg=train_cfg(),
        cost_model=cost_model_cfg(),
        seed=seed,
        cohort_mode=mode,
        task=make_task(num_examples=128, vocab_size=512, seq_len=8, seed=seed),
    )


def _one_cohort_round(runner: ExperimentRunner, cohort, rates):
    """One engine dispatch over the full cohort (fixed start trees/key, so
    repeated calls measure pure execution, not experiment drift)."""
    state = runner.state
    start = [state.global_peft] * len(cohort)
    _, _, outs = runner.ctx.engine.run_cohort(
        state.key, 0, cohort, rates, start, runner.ctx.num_classes,
        runner.ctx.cfg.num_layers,
    )
    return outs


def run(quick: bool = False):
    reps = 3 if quick else 10
    trials = 1 if quick else 3
    e2e_rounds = 4 if quick else 8
    runners = {mode: _make_runner(mode) for mode in ("sequential", "batched")}
    cohort = list(range(_DEVICES))
    rates = [0.5] * _DEVICES

    # ---------------------------------------------- engine: one cohort round
    engine = {mode: float("inf") for mode in runners}
    for runner in runners.values():  # compile/warm both paths
        _one_cohort_round(runner, cohort, rates)
    # interleave trials and keep per-mode minima: the shared container's
    # background load is additive noise that min-of-trials filters out
    for _ in range(trials):
        for mode, runner in runners.items():
            t0 = time.perf_counter()
            for _ in range(reps):
                outs = _one_cohort_round(runner, cohort, rates)
                jax.block_until_ready([o[0] for o in outs])
            engine[mode] = min(engine[mode], (time.perf_counter() - t0) / reps)
    for mode in engine:
        emit(
            f"cohort/engine_{mode}",
            engine[mode] * 1e6,
            f"devices={_DEVICES};reps={reps};trials={trials};smoke-config;steps1xb4xs8",
        )
    engine_speedup = engine["sequential"] / engine["batched"]
    emit("cohort/engine_speedup", 0.0, f"x{engine_speedup:.2f}")

    # ------------------------------- end-to-end runner rounds (reported)
    e2e = {}
    curves = {}
    # reuse the warmed runners so the timed rounds measure execution, not
    # compilation; both modes did identical engine-loop work above, so their
    # device data-sampler streams stay aligned and parity is preserved
    for mode, runner in runners.items():
        t0 = time.perf_counter()
        curves[mode] = runner.run(rounds=e2e_rounds)
        e2e[mode] = time.perf_counter() - t0
        emit(f"cohort/e2e_{mode}", e2e[mode] / e2e_rounds * 1e6, f"rounds={e2e_rounds}")
    # the two modes must also be running the SAME experiment (parity)
    parity = bool(
        np.allclose(curves["sequential"].loss, curves["batched"].loss, atol=1e-4)
        and np.allclose(curves["sequential"].accuracy, curves["batched"].accuracy, atol=1e-5)
    )
    emit("cohort/e2e_speedup", 0.0, f"x{e2e['sequential']/e2e['batched']:.2f};curves_match={parity}")

    print(
        json.dumps(
            {
                "bench": "cohort",
                "devices": _DEVICES,
                "engine_sequential_ms": round(engine["sequential"] * 1e3, 2),
                "engine_batched_ms": round(engine["batched"] * 1e3, 2),
                "engine_speedup": round(engine_speedup, 2),
                "e2e_speedup": round(e2e["sequential"] / e2e["batched"], 2),
                "curves_match": parity,
            }
        )
    )
    assert parity, "batched and sequential modes diverged for identical seeds"
    if not quick:
        assert engine_speedup >= 2.0, (
            f"batched cohort engine only {engine_speedup:.2f}x faster than the "
            f"sequential loop (claim: >= 2x for an {_DEVICES}-device cohort)"
        )


if __name__ == "__main__":
    run()
