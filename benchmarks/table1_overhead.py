"""Paper Table 1: per-round communication / computation / memory per device.

Analytic system model at the paper's scale (a ~1.5-2B LLM on a Jetson AGX
with 40 Mbps links).  Validates the paper's claims that (a) PEFT removes
>95% of communication but little compute/memory, and (b) DropPEFT (STLD at
the recommended 0.5 mean rate + PTLS half-sharing) cuts computation and
memory on top.
"""
from __future__ import annotations

from benchmarks.common import cost_model_cfg, emit
from repro.configs import PEFTConfig
from repro.federated.system_model import SystemModel


def run(quick: bool = False):
    cfg = cost_model_cfg()
    lora = PEFTConfig(method="lora", lora_rank=8)
    sm = SystemModel(cfg, lora)
    common = dict(device="agx", bandwidth_mbps=40.0, batch=16, seq=128, local_steps=32)

    rows = {
        "fft": sm.round_cost(peft=False, full_ft=True, **common),
        "peft_lora": sm.round_cost(peft=True, **common),
        "droppeft": sm.round_cost(peft=True, active_fraction=0.5, share_fraction=0.5, **common),
    }
    adapter = SystemModel(cfg, PEFTConfig(method="adapter", adapter_dim=64))
    rows["peft_adapter"] = adapter.round_cost(peft=True, **common)

    for name, c in rows.items():
        emit(
            f"table1/{name}",
            c.total_time_s * 1e6,
            f"comm_min={c.comm_time_s/60:.2f};comp_min={c.compute_time_s/60:.2f};mem_gb={c.memory_gb:.1f};traffic_mb={c.traffic_mb:.0f}",
        )

    # paper-claim checks (directional)
    assert rows["peft_lora"].comm_time_s < 0.05 * rows["fft"].comm_time_s, "PEFT kills >95% comm"
    peft_saving = 1 - rows["peft_lora"].memory_gb / rows["fft"].memory_gb
    assert peft_saving < 0.60, f"PEFT memory saving is limited (got {peft_saving:.2f})"
    assert rows["droppeft"].compute_time_s < 0.75 * rows["peft_lora"].compute_time_s, (
        "STLD at rate 0.5 must cut compute substantially"
    )
    mem_saving = 1 - rows["droppeft"].memory_gb / rows["peft_lora"].memory_gb
    assert 0.30 < mem_saving, f"DropPEFT memory saving {mem_saving:.2f} (paper: 40-67%)"
    emit("table1/droppeft_mem_saving_vs_peft", 0.0, f"fraction={mem_saving:.2f}")
