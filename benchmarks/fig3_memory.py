"""Paper Fig. 3: GPU-memory breakdown (params / activations / grads / optim).

Analytic at the paper's scale (batch 16, seq 256, AdamW) — checks the
paper's key observations: activations dominate PEFT memory (~80%), and
FFT splits roughly 11/55/11/23.
"""
from __future__ import annotations

from benchmarks.common import cost_model_cfg, emit
from repro.configs import PEFTConfig
from repro.federated.system_model import SystemModel


def run(quick: bool = False):
    cfg = cost_model_cfg()
    sm = SystemModel(cfg, PEFTConfig(method="lora", lora_rank=8))
    common = dict(batch=16, seq=256)

    fft = sm.memory_breakdown(peft=False, full_ft=True, **common)
    peft = sm.memory_breakdown(peft=True, **common)
    drop = sm.memory_breakdown(peft=True, active_fraction=0.5, **common)

    for name, m in (("fft", fft), ("peft", peft), ("droppeft", drop)):
        tot = m.total_gb
        emit(
            f"fig3/{name}",
            tot * 1000,
            f"params={m.params_gb/tot:.2f};act={m.activations_gb/tot:.2f};"
            f"grads={m.gradients_gb/tot:.2f};opt={m.optimizer_gb/tot:.2f};total_gb={tot:.1f}",
        )

    assert peft.activations_gb / peft.total_gb > 0.6, "activations dominate PEFT memory"
    assert drop.total_gb < 0.66 * peft.total_gb, "STLD ~halves memory at rate 0.5"
