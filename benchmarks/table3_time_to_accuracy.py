"""Paper Table 3: time-to-accuracy + final accuracy, all methods.

Federated simulation on the synthetic classification task; wall-clock from
the Jetson system model at 1.7B scale.  Validates the paper's headline:
DropPEFT reaches the target accuracy 1.3-6.3x faster than federated-PEFT
baselines and does not lose final accuracy.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, run_sim


def run(quick: bool = False):
    rounds = 6 if quick else 18
    methods = [
        ("fedlora", "lora"),
        ("fedhetlora", "lora"),
        ("droppeft", "lora"),
        ("fedadapter", "adapter"),
        ("fedadaopt", "adapter"),
    ]
    if not quick:
        methods.append(("droppeft", "adapter"))

    results = {}
    for strategy, peft in methods:
        name = f"{strategy}({peft})"
        res = run_sim(strategy, rounds=rounds, peft=peft, seed=1)
        results[name] = res
    # target = the best accuracy level every method SUSTAINS through the end
    # of its session; sustained time-to-accuracy means a single noisy round
    # that dips back below the target cannot win a speedup claim
    sustained_max = {
        n: float(np.minimum.accumulate(r.accuracy[::-1]).max()) for n, r in results.items()
    }
    target = max(min(sustained_max.values()) * 0.98, 0.3)

    for name, res in results.items():
        tta = res.time_to_accuracy(target, sustained=True)
        emit(
            f"table3/{name}",
            (tta or res.cum_time_s[-1]) * 1e6,
            f"tta_h={'%.2f' % (tta/3600) if tta else 'miss'};final_acc={res.final_accuracy:.3f};"
            f"last_acc={res.accuracy[-1]:.3f};target={target:.3f};"
            f"time_per_round_s={res.cum_time_s[-1]/res.rounds:.0f}",
        )

    # The robust, deterministic component of the paper's speedup is the
    # per-round wall-clock ratio (STLD compute + PTLS comm savings); the
    # accuracy-crossing component is cohort-noise-dominated at smoke scale
    # (fig13/fig14 cover it over longer sessions).
    t_drop = results["droppeft(lora)"].cum_time_s[-1] / results["droppeft(lora)"].rounds
    t_base = results["fedlora(lora)"].cum_time_s[-1] / results["fedlora(lora)"].rounds
    emit("table3/round_time_ratio_fedlora_over_droppeft", 0.0, f"x={t_base / t_drop:.2f}")
    assert t_base / t_drop > 1.2, f"per-round speedup {t_base/t_drop:.2f} (STLD must cut round time)"

    t_d = results["droppeft(lora)"].time_to_accuracy(target, sustained=True)
    t_b = results["fedlora(lora)"].time_to_accuracy(target, sustained=True)
    if t_d and t_b:
        emit("table3/tta_speedup_droppeft_vs_fedlora", 0.0, f"x={t_b / t_d:.2f} (noisy at smoke scale; paper: 1.3-6.3x)")
