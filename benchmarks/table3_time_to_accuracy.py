"""Paper Table 3: time-to-accuracy + final accuracy, all methods.

Federated simulation on the synthetic classification task; wall-clock from
the Jetson system model at 1.7B scale.  Validates the paper's headline:
DropPEFT reaches the target accuracy 1.3-6.3x faster than federated-PEFT
baselines and does not lose final accuracy.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, run_sim


def run(quick: bool = False):
    rounds = 6 if quick else 18
    methods = [
        ("fedlora", "lora"),
        ("fedhetlora", "lora"),
        ("droppeft", "lora"),
        ("fedadapter", "adapter"),
        ("fedadaopt", "adapter"),
    ]
    if not quick:
        methods.append(("droppeft", "adapter"))

    results = {}
    for strategy, peft in methods:
        name = f"{strategy}({peft})"
        res = run_sim(strategy, rounds=rounds, peft=peft, seed=1)
        results[name] = res
    # target = accuracy the slowest method eventually reaches (running-max
    # smoothing: accuracy fluctuates heavily in short smoke sessions)
    import numpy as np

    smooth = {n: np.maximum.accumulate(r.accuracy) for n, r in results.items()}
    target = max(min(float(s[-1]) for s in smooth.values()) * 0.98, 0.3)

    for name, res in results.items():
        hit = np.where(smooth[name] >= target)[0]
        tta = float(res.cum_time_s[hit[0]]) if len(hit) else None
        emit(
            f"table3/{name}",
            (tta or res.cum_time_s[-1]) * 1e6,
            f"tta_h={'%.2f' % (tta/3600) if tta else 'miss'};final_acc={res.final_accuracy:.3f};"
            f"last_acc={res.accuracy[-1]:.3f};target={target:.3f};"
            f"time_per_round_s={res.cum_time_s[-1]/res.rounds:.0f}",
        )

    # The robust, deterministic component of the paper's speedup is the
    # per-round wall-clock ratio (STLD compute + PTLS comm savings); the
    # accuracy-crossing component is cohort-noise-dominated at smoke scale
    # (fig13/fig14 cover it over longer sessions).
    t_drop = results["droppeft(lora)"].cum_time_s[-1] / results["droppeft(lora)"].rounds
    t_base = results["fedlora(lora)"].cum_time_s[-1] / results["fedlora(lora)"].rounds
    emit("table3/round_time_ratio_fedlora_over_droppeft", 0.0, f"x={t_base / t_drop:.2f}")
    assert t_base / t_drop > 1.2, f"per-round speedup {t_base/t_drop:.2f} (STLD must cut round time)"

    hit_d = np.where(smooth["droppeft(lora)"] >= target)[0]
    hit_b = np.where(smooth["fedlora(lora)"] >= target)[0]
    if len(hit_d) and len(hit_b):
        speedup = float(
            results["fedlora(lora)"].cum_time_s[hit_b[0]]
            / results["droppeft(lora)"].cum_time_s[hit_d[0]]
        )
        emit("table3/tta_speedup_droppeft_vs_fedlora", 0.0, f"x={speedup:.2f} (noisy at smoke scale; paper: 1.3-6.3x)")
