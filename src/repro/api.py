"""Single entry point for federated fine-tuning experiments.

Every consumer — benchmarks, examples, the launch driver — builds its
experiment here instead of hand-wiring configs into a simulator::

    from repro import api

    result = api.experiment(method="droppeft", rounds=10, seed=0)
    print(result.final_accuracy, result.time_to_accuracy(0.6, sustained=True))

``experiment`` is the one-shot path; ``build`` returns the underlying
:class:`~repro.federated.runner.ExperimentRunner` when the caller needs the
trained state afterwards (checkpointing, inspection, resuming)::

    runner = api.build(method="droppeft", checkpoint_dir="ckpts")
    result = runner.run(rounds=20, target_accuracy=0.8)
    peft = runner.state.global_peft

``method`` accepts a registered name (``api.list_methods()``), a
:class:`~repro.federated.algorithms.FederatedAlgorithm` instance (e.g. a
custom plugin subclass), or a legacy ``Strategy``.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Union

from repro.configs import (
    FederatedConfig,
    PEFTConfig,
    STLDConfig,
    TrainConfig,
    get_config,
)
from repro.federated.algorithms import (
    FederatedAlgorithm,
    get_algorithm,
    registered_methods,
)
from repro.federated.compression import CompressionConfig, resolve_compression
from repro.federated.runner import ExperimentRunner, SimResult, fresh_algorithm
from repro.federated.scheduler import ScheduleConfig, resolve_schedule

__all__ = [
    "build",
    "experiment",
    "replicate",
    "serve",
    "list_methods",
    "ScheduleConfig",
    "CompressionConfig",
]


def list_methods() -> List[str]:
    """Names accepted by ``method=`` (the algorithm registry)."""
    return registered_methods()


def _resolve_algorithm(method, fixed_rate: Optional[float]) -> FederatedAlgorithm:
    if isinstance(method, str):
        algorithm: FederatedAlgorithm = get_algorithm(method)()
    elif isinstance(method, FederatedAlgorithm):
        algorithm = method
    else:  # legacy Strategy flag table
        from repro.federated.simulator import algorithm_from_strategy

        algorithm = algorithm_from_strategy(method)
    if fixed_rate is not None:
        # an explicit fixed rate overrides the bandit (0.0 is a valid sweep
        # point: "unset" is spelled None, never falsiness); copy first so a
        # caller-owned instance is never mutated
        algorithm = fresh_algorithm(algorithm)
        algorithm.use_configurator = False
        algorithm.fixed_rate = float(fixed_rate)
    return algorithm


def build(
    method: Union[str, FederatedAlgorithm, object] = "droppeft",
    model: str = "qwen3-1.7b",
    *,
    smoke: bool = True,
    cfg=None,
    model_overrides: Optional[dict] = None,
    # PEFT
    peft: str = "lora",
    lora_rank: Optional[int] = None,
    adapter_dim: Optional[int] = None,
    peft_cfg: Optional[PEFTConfig] = None,
    # STLD
    stld_mode: str = "cond",
    mean_rate: Optional[float] = None,
    distribution: str = "incremental",
    stld_cfg: Optional[STLDConfig] = None,
    # federated round structure
    fed_cfg: Optional[FederatedConfig] = None,
    train_cfg: Optional[TrainConfig] = None,
    # method policy
    fixed_rate: Optional[float] = None,
    # virtual-clock scheduling: a policy name ("sync" | "deadline" |
    # "async-buffer") or a full ScheduleConfig; the scalar kwargs override
    # individual fields of whichever config `schedule` resolves to
    schedule: Union[str, ScheduleConfig, None] = None,
    deadline_s: Optional[float] = None,
    straggler: Optional[str] = None,
    buffer_size: Optional[int] = None,
    staleness_alpha: Optional[float] = None,
    # uplink compression: a level name ("none" | "int8" | "topk" |
    # "int8+topk"), "auto" (joint bandit over levels), a dict of
    # CompressionConfig fields, or a CompressionConfig; None (default) skips
    # the compression machinery entirely — bit-identical to pre-compression
    # rounds
    compression: Union[str, dict, CompressionConfig, None] = None,
    topk_fraction: Optional[float] = None,
    # pinned hardware mix (one profile name per device); None -> sampled
    device_profile: Optional[Sequence[str]] = None,
    # system-model cost scale: None -> the training cfg; an arch name or a
    # ModelConfig -> cost accounting at that (e.g. full 1.7B) scale
    cost_model=None,
    task=None,
    seed: int = 0,
    cohort_mode: str = "auto",
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    resume: bool = False,
    # fault injection: a FaultPlan, a kwargs dict, or a JSON-file path
    # (see repro.federated.faults); None runs fault-free
    fault_plan=None,
) -> ExperimentRunner:
    """Construct a fully-wired :class:`ExperimentRunner` (does not run it)."""
    if cfg is None:
        cfg = get_config(model, smoke=smoke)
    if model_overrides:
        cfg = cfg.replace(**model_overrides)
    if peft_cfg is None:
        kw = {"method": peft}
        if lora_rank is not None:
            kw["lora_rank"] = lora_rank
        if adapter_dim is not None:
            kw["adapter_dim"] = adapter_dim
        peft_cfg = PEFTConfig(**kw)
    if stld_cfg is None:
        if mean_rate is None:
            mean_rate = 0.5 if fixed_rate is None else fixed_rate
        stld_cfg = STLDConfig(
            mode=stld_mode, mean_rate=mean_rate, distribution=distribution
        )
    if fed_cfg is None:
        fed_cfg = FederatedConfig()
    if train_cfg is None:
        train_cfg = TrainConfig()
    if isinstance(cost_model, str):
        cost_model = get_config(cost_model)
    return ExperimentRunner(
        cfg,
        peft_cfg,
        stld_cfg,
        fed_cfg,
        train_cfg,
        algorithm=_resolve_algorithm(method, fixed_rate),
        task=task,
        cost_cfg=cost_model,
        seed=seed,
        cohort_mode=cohort_mode,
        schedule=resolve_schedule(
            schedule,
            deadline_s=deadline_s,
            straggler=straggler,
            buffer_size=buffer_size,
            staleness_alpha=staleness_alpha,
        ),
        device_profile=device_profile,
        checkpoint_dir=checkpoint_dir,
        checkpoint_every=checkpoint_every,
        resume=resume,
        fault_plan=fault_plan,
        compression=resolve_compression(compression, topk_fraction=topk_fraction),
    )


def serve(
    model: str = "qwen3-1.7b",
    *,
    smoke: bool = True,
    cfg=None,
    model_overrides: Optional[dict] = None,
    params=None,
    # adapter sources: a federated checkpoint dir and/or named trees
    checkpoint_dir: Optional[str] = None,
    adapters: Optional[dict] = None,
    lora_alpha: float = 16.0,
    # serving shape
    batch: int = 4,
    max_len: int = 256,
    n_slots: Optional[int] = None,
    stack_mode: str = "scan",
    cache_dtype: str = "bfloat16",
    seed: int = 0,
):
    """Multi-tenant adapter serving: a ready :class:`ContinuousBatcher`.

    Adapters come from a federated ``save_state`` checkpoint
    (``checkpoint_dir`` — every client's adapter registers as
    ``client<id>``) and/or an explicit ``{name: peft_tree}`` dict.  Submit
    :class:`~repro.serving.batcher.Request`s against adapter names and call
    ``run()``; heterogeneous ranks, prompts, and stop conditions share one
    compiled decode step.
    """
    import jax
    import jax.numpy as jnp

    from repro.launch.steps import make_serve_step
    from repro.models import init_params
    from repro.serving.adapters import AdapterPoolCache, AdapterRegistry
    from repro.serving.batcher import ContinuousBatcher

    if cfg is None:
        cfg = get_config(model, smoke=smoke)
    if model_overrides:
        cfg = cfg.replace(**model_overrides)
    if params is None:
        params = init_params(jax.random.PRNGKey(seed), cfg)
    registry = AdapterRegistry()
    if checkpoint_dir is not None:
        registry.load_checkpoint(checkpoint_dir, alpha=lora_alpha)
    for name, tree in (adapters or {}).items():
        registry.register(name, tree, alpha=lora_alpha)
    if len(registry) == 0:
        raise ValueError("no adapters: pass checkpoint_dir and/or adapters")
    pool = AdapterPoolCache(
        registry, n_slots=n_slots if n_slots is not None else max(batch, len(registry))
    )
    serve_step = make_serve_step(cfg, stack_mode=stack_mode)
    return ContinuousBatcher(
        serve_step,
        params,
        cfg,
        pool,
        batch=batch,
        max_len=max_len,
        cache_dtype=jnp.dtype(cache_dtype),
    )


def experiment(
    method: Union[str, FederatedAlgorithm, object] = "droppeft",
    model: str = "qwen3-1.7b",
    *,
    rounds: Optional[int] = None,
    target_accuracy: Optional[float] = None,
    **kwargs,
) -> SimResult:
    """Build and run one federated experiment; returns its SimResult."""
    runner = build(method, model, **kwargs)
    return runner.run(rounds=rounds, target_accuracy=target_accuracy)


def replicate(
    method: Union[str, FederatedAlgorithm] = "droppeft",
    model: str = "qwen3-1.7b",
    *,
    seeds: Sequence[int] = (0, 1, 2),
    rounds: Optional[int] = None,
    target_accuracy: Optional[float] = None,
    **kwargs,
) -> List[SimResult]:
    """Multi-seed replication: one independent experiment per seed."""
    results = []
    for seed in seeds:
        kw = dict(kwargs)
        kw["seed"] = seed
        # each seed gets a fresh, configuration-preserving algorithm copy so
        # replicates are independent and the caller's instance stays unbound
        runner = build(fresh_algorithm(method), model, **kw)
        results.append(runner.run(rounds=rounds, target_accuracy=target_accuracy))
    return results
