"""Continuous-batching request scheduler for multi-tenant LoRA decode.

Orca-style token-level scheduling: the compiled program is ONE fixed-shape
``(batch, 1)`` decode step — every wall-clock step each live row consumes
one token (prompt tokens stream through the same program as generated
ones), and finished rows are recycled for queued requests between steps.
Admission, stop handling, and slot recycling are host-side bookkeeping;
nothing about the device program changes when requests come and go, so the
steady state runs a single compile no matter how tenants interleave.

Each row serves its own tenant: the row's adapter is resolved through
:class:`~repro.serving.adapters.AdapterPoolCache` and applied by the
segmented gather kernel via per-row slot indices — distinct adapters,
prompt lengths, and stop conditions coexist in one batch.

Per-row KV state lives in a batched cache (``pos`` is a ``(B,)`` vector):
recycling a row just resets its position to zero — ring-position masking in
``attention_apply`` keeps the previous tenant's stale K/V inert without a
cache clear.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import init_caches
from repro.serving.adapters import AdapterPoolCache


@dataclass
class Request:
    """One generation request bound to a named adapter."""

    prompt: Sequence[int]
    adapter: str
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    uid: Any = None


@dataclass
class Completion:
    """Finished request: the tokens generated after the prompt."""

    uid: Any
    adapter: str
    tokens: List[int] = field(default_factory=list)
    finish_reason: str = "length"  # "length" | "eos"


@dataclass
class _Row:
    req: Request
    remaining_prompt: List[int]
    generated: List[int] = field(default_factory=list)
    slot: int = 0


@jax.jit
def _reset_rows(caches, pos_mask):
    """Zero the cache positions of recycled rows (pos_mask: (B,) bool).

    Only positions reset — the stale K/V of the previous request stays in
    the ring and is masked out by position (see ``attention_apply``)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: (
            jnp.where(pos_mask, 0, x)
            if getattr(p[-1], "key", None) == "pos"
            else x
        ),
        caches,
    )


def batched_caches(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked-layout caches with per-row ``(B,)`` positions."""
    caches = init_caches(cfg, batch, max_len, dtype, layout="stacked")
    return jax.tree_util.tree_map_with_path(
        lambda p, x: (
            jnp.zeros(x.shape + (batch,), x.dtype)
            if getattr(p[-1], "key", None) == "pos"
            else x
        ),
        caches,
    )


class ContinuousBatcher:
    """Admit, step, and drain multi-tenant generation requests.

    ``serve_step`` is the callable from ``make_serve_step`` (peft-aware);
    the batcher jit-compiles one wrapper around it and reuses that compile
    for the whole serving session — adapter swaps, admissions, and
    recycles only change traced data.
    """

    def __init__(
        self,
        serve_step,
        params,
        cfg,
        pool: AdapterPoolCache,
        *,
        batch: int,
        max_len: int,
        cache_dtype=jnp.bfloat16,
        pad_id: int = 0,
    ):
        self.params = params
        self.cfg = cfg
        self.pool = pool
        self.batch = int(batch)
        self.max_len = int(max_len)
        self.pad_id = int(pad_id)
        self.queue: List[Request] = []
        self.done: List[Completion] = []
        self.rows: List[Optional[_Row]] = [None] * self.batch
        self.caches = batched_caches(cfg, self.batch, self.max_len, cache_dtype)
        self._tokens = np.full((self.batch,), pad_id, np.int32)
        self._pos = np.zeros((self.batch,), np.int32)

        def step_fn(params, peft, token, pos, caches):
            return serve_step(params, token, pos, caches, peft=peft)

        self._step = jax.jit(step_fn)

    # -------------------------------------------------------------- admit
    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError("empty prompt")
        if len(req.prompt) + 1 > self.max_len:
            # prompt prefill + at least one generated token must fit in the
            # KV ring, else teacher-forced prefill silently wraps it
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens needs "
                f"{len(req.prompt) + 1} cache positions but max_len is "
                f"{self.max_len}"
            )
        self.queue.append(req)

    def _admit(self):
        """Fill free rows from the queue; reset recycled rows' positions.

        Each admitted row ``acquire``s its adapter, holding the pool slot
        until the row completes — eviction can never rewrite a slot a live
        row still decodes with.  A request whose adapter cannot be loaded
        yet (every slot pinned by live rows) stays queued; later queued
        requests whose adapters are already resident may admit ahead of it.
        """
        freed = np.zeros((self.batch,), bool)
        for i in range(self.batch):
            if self.rows[i] is not None or not self.queue:
                continue
            admitted = None
            for qi, req in enumerate(self.queue):
                try:
                    slot = self.pool.acquire(req.adapter)
                except RuntimeError:
                    continue  # all slots held by live rows; leave queued
                admitted = _Row(
                    req=req, remaining_prompt=list(req.prompt), slot=slot
                )
                self.queue.pop(qi)
                break
            if admitted is None:
                break  # nothing admissible until a live row releases a pin
            self.rows[i] = admitted
            self._tokens[i] = admitted.remaining_prompt.pop(0)
            self._pos[i] = 0
            freed[i] = True
        if freed.any():
            self.caches = _reset_rows(self.caches, jnp.asarray(freed))

    # --------------------------------------------------------------- step
    def step(self):
        """One fused decode step over all live rows."""
        self._admit()
        live = [i for i in range(self.batch) if self.rows[i] is not None]
        if not live:
            return False
        slots = [self.rows[i].slot if self.rows[i] else 0 for i in range(self.batch)]
        peft = self.pool.pooled_peft(jnp.asarray(slots, jnp.int32))
        _, nxt, self.caches = self._step(
            self.params,
            peft,
            jnp.asarray(self._tokens)[:, None],
            jnp.asarray(self._pos),
            self.caches,
        )
        nxt = np.asarray(nxt)[:, 0].tolist()  # one transfer for the batch
        self._pos += 1
        for i in live:
            row = self.rows[i]
            if row.remaining_prompt:
                # prompt still streaming: the model's prediction is ignored,
                # the next prompt token is forced (teacher-forced prefill
                # through the decode program — no separate prefill compile)
                self._tokens[i] = row.remaining_prompt.pop(0)
                continue
            tok = nxt[i]
            row.generated.append(tok)
            hit_eos = row.req.eos_id is not None and tok == row.req.eos_id
            out_of_budget = len(row.generated) >= row.req.max_new_tokens
            out_of_cache = bool(self._pos[i] >= self.max_len)
            if hit_eos or out_of_budget or out_of_cache:
                self.done.append(
                    Completion(
                        uid=row.req.uid,
                        adapter=row.req.adapter,
                        tokens=list(row.generated),
                        finish_reason="eos" if hit_eos else "length",
                    )
                )
                self.pool.release(row.req.adapter)
                self.rows[i] = None  # row recycles next _admit()
                self._tokens[i] = self.pad_id
                self._pos[i] = 0
            else:
                self._tokens[i] = tok
        return True

    # ---------------------------------------------------------------- run
    def run(self, max_steps: int = 100_000) -> List[Completion]:
        """Step until queue and rows drain; returns completions in finish
        order.  Raises rather than silently dropping work: if ``max_steps``
        is exhausted with requests still in flight, or the queue cannot
        make progress (every pool slot pinned outside the batcher), every
        submitted-but-unfinished request would otherwise vanish."""
        steps = 0
        while (self.queue or any(r is not None for r in self.rows)) and steps < max_steps:
            if not self.step() and self.queue:
                raise RuntimeError(
                    f"{len(self.queue)} queued request(s) cannot be "
                    f"admitted: all {self.pool.n_slots} pool slots are "
                    f"pinned outside the batcher"
                )
            steps += 1
        live = sum(r is not None for r in self.rows)
        if self.queue or live:
            raise RuntimeError(
                f"run() exhausted max_steps={max_steps} with {live} live "
                f"row(s) and {len(self.queue)} queued request(s) — their "
                f"completions were never emitted"
            )
        out, self.done = self.done, []
        return out
