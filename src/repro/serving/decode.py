"""Serving utilities.

``sharded_decode_attention`` — beyond-paper distributed decode for
``long_500k``-class workloads: the KV cache is sharded along the *sequence*
dimension across the ``data`` mesh axis; each shard computes its partial
attention and the partials merge with a log-sum-exp ``psum`` combine under
``shard_map``.  Per-token decode traffic is O(heads x head_dim) instead of
all-gathering an O(seq) cache.

``generate`` — simple greedy KV-cache generation driver used by examples
and integration tests (single host, any arch via serve_step).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _partial_attention(q, k, v, k_positions, q_position, window):
    """Unnormalised attention over one KV shard.

    q: (B, H, D); k, v: (B, S_shard, KV, D).  Returns (acc (B,H,D), m, l).
    """
    n_rep = q.shape[1] // k.shape[2]
    kk = jnp.repeat(k, n_rep, axis=2)  # (B, S, H, D)
    vv = jnp.repeat(v, n_rep, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kk.astype(jnp.float32))
    scores = scores * (q.shape[-1] ** -0.5)
    ok = k_positions <= q_position
    if window is not None and window > 0:
        ok = ok & (k_positions > q_position - window)
    scores = jnp.where(ok[None, None, :], scores, -1e30)
    m = jnp.max(scores, axis=-1)  # (B, H)
    p = jnp.exp(scores - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhs,bshd->bhd", p, vv.astype(jnp.float32))
    return acc, m, l


def sharded_decode_attention(mesh, q, k_cache, v_cache, k_positions, q_position, *, window=None, axis: str = "data"):
    """Flash-decode over a sequence-sharded KV cache.

    q: (B, H, D) replicated; k_cache/v_cache: (B, S, KV, D) sharded on S over
    ``axis``; k_positions: (S,) absolute slot positions (sharded alike).
    Returns (B, H, D) attention output, replicated.
    """
    from jax.experimental.shard_map import shard_map

    def local(q, k, v, kpos):
        acc, m, l = _partial_attention(q, k, v, kpos, q_position, window)
        # log-sum-exp combine across sequence shards
        m_glob = jax.lax.pmax(m, axis)
        scale = jnp.exp(m - m_glob)
        l_glob = jax.lax.psum(l * scale, axis)
        acc_glob = jax.lax.psum(acc * scale[..., None], axis)
        return (acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]).astype(q.dtype)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None), P(axis)),
        out_specs=P(),
        check_rep=False,
    )(q, k_cache, v_cache, k_positions)


def generate(
    serve_step,
    params,
    prompt_caches,
    first_token,
    start_pos: int,
    num_tokens: int,
    enc_kvs=None,
    *,
    eos_id=None,
    max_new_tokens=None,
    pad_id: int = 0,
):
    """Greedy generation loop.  Returns (tokens (B, num_tokens), caches).

    Per-sequence stop handling: once a row emits ``eos_id`` or reaches its
    ``max_new_tokens`` budget (scalar or per-row ``(B,)``), that row is
    frozen — subsequent output positions hold ``pad_id`` and the frozen
    row keeps feeding its last live token so cache writes stay inert for
    ranking purposes (the scan still runs ``num_tokens`` steps; rows stop
    independently, the batch shape never changes).  With both ``eos_id``
    and ``max_new_tokens`` unset this is exactly the unconditional loop.
    """
    if eos_id is None and max_new_tokens is None:

        def body(carry, _):
            token, pos, caches = carry
            if enc_kvs is None:
                _, nxt, caches = serve_step(params, token, pos, caches)
            else:
                _, nxt, caches = serve_step(params, token, pos, caches, enc_kvs)
            return (nxt, pos + 1, caches), nxt[:, 0]

        (_, _, caches), toks = jax.lax.scan(
            body, (first_token, jnp.asarray(start_pos, jnp.int32), prompt_caches), None, length=num_tokens
        )
        return toks.swapaxes(0, 1), caches

    batch = first_token.shape[0]
    budget = None
    if max_new_tokens is not None:
        budget = jnp.broadcast_to(jnp.asarray(max_new_tokens, jnp.int32), (batch,))

    def body(carry, step):
        token, pos, caches, done = carry
        if enc_kvs is None:
            _, nxt, caches = serve_step(params, token, pos, caches)
        else:
            _, nxt, caches = serve_step(params, token, pos, caches, enc_kvs)
        emitted = jnp.where(done, jnp.asarray(pad_id, nxt.dtype), nxt[:, 0])
        new_done = done
        if eos_id is not None:
            new_done = new_done | (~done & (nxt[:, 0] == eos_id))
        if budget is not None:
            new_done = new_done | (step + 1 >= budget)
        # frozen rows re-feed their previous token (value is irrelevant —
        # their outputs are masked; keeping shapes fixed avoids recompiles)
        nxt = jnp.where(done[:, None], token, nxt)
        return (nxt, pos + 1, caches, new_done), emitted

    init = (
        first_token,
        jnp.asarray(start_pos, jnp.int32),
        prompt_caches,
        jnp.zeros((batch,), bool),
    )
    (_, _, caches, _), toks = jax.lax.scan(
        body, init, jnp.arange(num_tokens, dtype=jnp.int32)
    )
    return toks.swapaxes(0, 1), caches
