"""Adapter registry + pooled LRU cache for multi-tenant LoRA serving.

:class:`AdapterRegistry` is the host-side catalogue: named LoRA trees (one
per federated client, loaded straight from ``save_state`` checkpoints or
registered in-process) with their rank and alpha.  Mixed ranks are the norm
— hetlora trains clients at different ranks — and each entry keeps its true
rank alongside the tree.

:class:`AdapterPoolCache` owns the device-resident pools the segmented
kernel reads: for every LoRA projection a stacked ``(L, n_slots, ...)``
pool, zero-padded to the pool-wide ``r_max``, with the per-adapter
``alpha / rank`` scale pre-folded into ``b`` at slot-write time (the kernel
deliberately has no scale operand — see ``kernels/segmented_lora``).  Slot
writes go through one jitted program whose slot index is *traced*, so
hot-swapping an adapter into a recycled slot re-runs a compiled scatter —
pool shapes are static and nothing recompiles.  Eviction is LRU over
unpinned slots; pins are refcounted so every live request holds its
adapter's slot (``acquire``/``release``) and eviction can never rewrite a
slot that a mid-generation row still reads.
"""
from __future__ import annotations

import os
from collections import OrderedDict
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as ckpt_lib
from repro.models import stacking
from repro.nn.linear import AdapterPool

DEFAULT_LORA_ALPHA = 16.0  # PEFTConfig default


def _is_lora_node(node) -> bool:
    return isinstance(node, dict) and set(node.keys()) == {"a", "b"}


def _walk(node, fn, path=()):
    """Apply ``fn`` to every LoRA ``{"a","b"}`` node; rebuild around it."""
    if _is_lora_node(node):
        return fn(node, path)
    if isinstance(node, dict):
        return {k: _walk(v, fn, path + (k,)) for k, v in node.items()}
    raise ValueError(
        f"pooled serving supports pure-LoRA peft trees; found non-LoRA node "
        f"at {'/'.join(path) or '<root>'}: {type(node).__name__}"
    )


def infer_rank(peft_tree) -> int:
    """True rank of a LoRA tree = trailing dim of any ``a`` leaf."""
    ranks = set()
    _walk(peft_tree, lambda n, p: ranks.add(int(n["a"].shape[-1])) or n)
    if len(ranks) != 1:
        raise ValueError(f"mixed ranks within one adapter tree: {sorted(ranks)}")
    return ranks.pop()


class AdapterRegistry:
    """Named catalogue of per-tenant LoRA trees (stacked layout)."""

    def __init__(self):
        self._entries: Dict[str, dict] = {}

    def register(self, name: str, peft_tree, *, alpha: float = DEFAULT_LORA_ALPHA):
        """Register a LoRA tree (list or stacked layout) under ``name``."""
        if isinstance(peft_tree, (list, tuple)):
            peft_tree = stacking.stack_params(list(peft_tree))
        rank = infer_rank(peft_tree)
        self._entries[name] = {"peft": peft_tree, "rank": rank, "alpha": float(alpha)}
        return self

    def load_checkpoint(
        self,
        checkpoint_dir: str,
        *,
        prefix: str = "client",
        alpha: float = DEFAULT_LORA_ALPHA,
    ):
        """Register every client adapter from a federated ``save_state``
        checkpoint.  ``checkpoint_dir`` may be a ``step_*`` dir, a run dir
        whose latest step is used, or a trainer ``--ckpt-dir`` root holding
        one arch-named run dir.  Clients land as ``f"{prefix}{device_id}"``;
        the server-side global adapter as ``f"{prefix}_global"``.
        """
        state_dir = self._resolve_state_dir(checkpoint_dir)
        arrays = self._load_arrays(state_dir)
        device_peft = arrays.get("device_peft", {})
        for dev, tree in device_peft.items():
            self.register(f"{prefix}{dev}", tree, alpha=alpha)
        if arrays.get("global_peft") is not None:
            self.register(f"{prefix}_global", arrays["global_peft"], alpha=alpha)
        return self

    @staticmethod
    def _resolve_state_dir(checkpoint_dir: str) -> str:
        latest = ckpt_lib.latest_state_dir(checkpoint_dir)
        if latest is not None:
            return latest
        if os.path.isfile(os.path.join(checkpoint_dir, "manifest.json")):
            return checkpoint_dir  # already a step_* dir
        runs = []
        if os.path.isdir(checkpoint_dir):
            for name in sorted(os.listdir(checkpoint_dir)):
                sub = ckpt_lib.latest_state_dir(os.path.join(checkpoint_dir, name))
                if sub is not None:
                    runs.append(sub)
        if len(runs) == 1:
            return runs[0]
        raise FileNotFoundError(
            f"no checkpoint under {checkpoint_dir!r}"
            + (f"; {len(runs)} run dirs found — pass one of them" if runs else "")
        )

    @staticmethod
    def _load_arrays(state_dir: str) -> dict:
        """Read either checkpoint schema as a ``{"global_peft", "device_peft"}``
        dict: the runner's ``save_state`` (JSON skeleton) directly, or a
        ``save_pytree`` manifest (``launch/train.py`` saves only the global
        adapter that way) by rebuilding the nested dict from leaf paths."""
        import json

        import numpy as np

        with open(os.path.join(state_dir, "manifest.json")) as f:
            manifest = json.load(f)
        if "skeleton" in manifest:
            return ckpt_lib.load_state(state_dir)[0]
        data = np.load(os.path.join(state_dir, "arrays.npz"))
        tree: dict = {}
        for entry in manifest["leaves"]:
            arr = data[entry["key"]]
            if entry["dtype"] == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            *parents, leaf = entry["path"].split("/")
            node = tree
            for p in parents:
                node = node.setdefault(p, {})
            node[leaf] = arr
        return {"global_peft": tree, "device_peft": {}}

    def get(self, name: str) -> dict:
        return self._entries[name]

    def names(self):
        return list(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._entries)


@partial(jax.jit, donate_argnums=0)
def _write_slot(pool_tree, padded_tree, slot):
    """Compiled slot write: ``pool[:, slot] = adapter`` on every leaf.
    ``slot`` is traced — swaps at different slots reuse this compile.
    The pool is donated so the scatter updates the buffers in place
    instead of materializing an O(L*n_slots*K*r_max) copy per swap."""
    return jax.tree.map(
        lambda pool, x: pool.at[:, slot].set(x.astype(pool.dtype)),
        pool_tree,
        padded_tree,
    )


class AdapterPoolCache:
    """LRU slot cache mapping registry adapters into device pools.

    ``n_slots`` bounds concurrent tenants per compiled batch; ``r_max``
    (default: max rank in the registry) sizes the shared rank padding.
    """

    def __init__(self, registry: AdapterRegistry, n_slots: int, r_max: Optional[int] = None):
        if len(registry) == 0:
            raise ValueError("registry is empty")
        self.registry = registry
        self.n_slots = int(n_slots)
        self.r_max = int(
            r_max
            if r_max is not None
            else max(registry.get(n)["rank"] for n in registry.names())
        )
        self._slots: "OrderedDict[str, int]" = OrderedDict()  # name -> slot (LRU order)
        self._pins: Dict[str, int] = {}  # name -> refcount (>0 blocks eviction)
        template = registry.get(registry.names()[0])["peft"]
        # pools: same structure as a client tree, every LoRA leaf grows a
        # slot axis after the layer axis: a (L, K, r) -> (L, NS, K, r_max)
        def pool_leaf(node, _path):
            a, b = node["a"], node["b"]
            lnum = a.shape[0]
            return {
                "a": jnp.zeros((lnum, self.n_slots, a.shape[1], self.r_max), jnp.float32),
                "b": jnp.zeros((lnum, self.n_slots, self.r_max, b.shape[-1]), jnp.float32),
            }

        self._pool = _walk(template, pool_leaf)
        self._ranks = jnp.zeros((self.n_slots,), jnp.int32)
        self.swaps = 0  # slot writes performed (steady-state swap telemetry)

    # ------------------------------------------------------------ slots
    def _padded(self, entry):
        """Zero-pad an adapter to r_max and pre-fold alpha/rank into b."""
        scale = entry["alpha"] / entry["rank"]
        pad_r = self.r_max - entry["rank"]

        def pad(node, _path):
            a, b = node["a"], node["b"]
            return {
                "a": jnp.pad(a, ((0, 0), (0, 0), (0, pad_r))),
                "b": jnp.pad(b * jnp.asarray(scale, b.dtype), ((0, 0), (0, pad_r), (0, 0))),
            }

        return _walk(entry["peft"], pad)

    def slot_of(self, name: str) -> int:
        """Slot holding ``name``, loading (and possibly evicting) if absent."""
        if name in self._slots:
            self._slots.move_to_end(name)
            return self._slots[name]
        entry = self.registry.get(name)
        if entry["rank"] > self.r_max:
            raise ValueError(
                f"adapter {name!r} rank {entry['rank']} exceeds pool r_max {self.r_max}"
            )
        if len(self._slots) < self.n_slots:
            slot = len(self._slots)
        else:
            victim = next(
                (n for n in self._slots if self._pins.get(n, 0) == 0), None
            )
            if victim is None:
                raise RuntimeError("all pool slots are pinned; cannot evict")
            slot = self._slots.pop(victim)
        # traced slot index: same compiled scatter for every swap
        self._pool = _write_slot(self._pool, self._padded(entry), jnp.asarray(slot))
        self._ranks = self._ranks.at[slot].set(entry["rank"])
        self._slots[name] = slot
        self.swaps += 1
        return slot

    def lookup(self, names) -> jnp.ndarray:
        """Row -> slot map for a batch of adapter names, loading as needed.

        Every distinct name is pinned while the batch resolves, so loading
        name k+1 can never evict the slot just handed out for name k.  The
        pins are dropped on return — the map stays valid only until the
        next adapter load, so callers interleaving loads with use should
        hold their own ``acquire``/``release`` pins (the batcher does).
        """
        distinct = list(dict.fromkeys(names))
        if len(distinct) > self.n_slots:
            raise ValueError(
                f"batch references {len(distinct)} distinct adapters but the "
                f"pool has only {self.n_slots} slots"
            )
        held = []
        try:
            for n in distinct:
                self.pin(n)
                held.append(n)
            return jnp.asarray([self._slots[n] for n in names], jnp.int32)
        finally:
            for n in held:
                self.unpin(n)

    def acquire(self, name: str) -> int:
        """``slot_of`` + a refcounted pin: the slot cannot be evicted until
        a matching :meth:`release`.  Every live request row must hold one."""
        slot = self.slot_of(name)
        self._pins[name] = self._pins.get(name, 0) + 1
        return slot

    def release(self, name: str):
        """Drop one ``acquire`` pin; the slot becomes evictable at zero."""
        count = self._pins.get(name, 0) - 1
        if count > 0:
            self._pins[name] = count
        else:
            self._pins.pop(name, None)

    def pin(self, name: str):
        self.acquire(name)

    def unpin(self, name: str):
        self.release(name)

    # ------------------------------------------------------------- peft
    def pooled_peft(self, row_slots):
        """Peft tree with :class:`AdapterPool` nodes for a batch whose row i
        serves the adapter in slot ``row_slots[i]``.  Pool arrays are shared
        (no copies); ``idx``/``ranks`` broadcast to the leading layer axis so
        ``layer_view``/scan slicing pass through unchanged.
        """
        row_slots = jnp.asarray(row_slots, jnp.int32)

        def wrap(node, _path):
            lnum = node["a"].shape[0]
            return AdapterPool(
                a=node["a"],
                b=node["b"],
                idx=jnp.broadcast_to(row_slots[None], (lnum, row_slots.shape[0])),
                ranks=jnp.broadcast_to(self._ranks[None], (lnum, self.n_slots)),
            )

        return _walk(self._pool, wrap)
