from repro.serving.adapters import AdapterPoolCache, AdapterRegistry
from repro.serving.batcher import (
    Completion,
    ContinuousBatcher,
    Request,
    batched_caches,
)
from repro.serving.decode import generate, sharded_decode_attention

__all__ = [
    "AdapterPoolCache",
    "AdapterRegistry",
    "Completion",
    "ContinuousBatcher",
    "Request",
    "batched_caches",
    "generate",
    "sharded_decode_attention",
]
