from repro.serving.decode import generate, sharded_decode_attention

__all__ = ["generate", "sharded_decode_attention"]
