"""Shared violation record + report rendering for the analysis passes.

Every pass (jaxpr contracts, AST lint, recompile guard) reports findings as
:class:`Violation` rows so the CLI and the tests consume one shape.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List


@dataclass(frozen=True)
class Violation:
    """One static-analysis finding.

    ``rule`` is the rule id (``JXH002``, ``restack``, ...); ``where`` locates
    it — ``path:line`` for lint findings, ``algorithm/program`` for jaxpr
    contracts; ``hint`` says how to fix (or suppress) it.
    """

    rule: str
    where: str
    message: str
    hint: str = ""

    def render(self) -> str:
        out = f"{self.where}: [{self.rule}] {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


def render_report(violations: Iterable[Violation], *, title: str) -> str:
    rows: List[Violation] = list(violations)
    lines = [f"== {title}: {len(rows)} violation(s) =="]
    lines += [v.render() for v in rows]
    return "\n".join(lines)
