"""JAX-aware static analysis: jaxpr contracts, AST lint, recompile guard.

Run everything over the registered algorithms with::

    PYTHONPATH=src python -m repro.analysis

See ``python -m repro.analysis --help`` for pass selection, the negative
fixtures (``--fixture RULE`` / ``--self-test``), and rule listing.
"""
from repro.analysis.jaxpr_contracts import (
    CONTRACT_RULES,
    ProgramTrace,
    ScalingCurve,
    check_algorithms,
    estimate_flops,
    stacking_concats,
    walk_eqns,
)
from repro.analysis.lint_jax import LINT_RULES, lint_paths, lint_source
from repro.analysis.recompile_guard import (
    CompilationCounter,
    RecompileBudgetExceeded,
    check_experiment_recompiles,
    recompile_guard,
)
from repro.analysis.report import Violation, render_report

__all__ = [
    "CONTRACT_RULES",
    "LINT_RULES",
    "CompilationCounter",
    "ProgramTrace",
    "RecompileBudgetExceeded",
    "ScalingCurve",
    "Violation",
    "check_algorithms",
    "check_experiment_recompiles",
    "estimate_flops",
    "lint_paths",
    "lint_source",
    "recompile_guard",
    "render_report",
    "stacking_concats",
    "walk_eqns",
]
