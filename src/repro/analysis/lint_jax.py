"""AST lint for JAX hazards, tuned to this repo's idioms.

The runtime test tiers prove numerical parity; this pass catches the class
of bug that parity tests structurally cannot — code that is *correct* but
silently slow (per-element host syncs), *correct today* but fragile (a PRNG
key consumed twice, a static argname that no longer matches the signature),
or wrong only under conditions CI never hits (an environment query baked
into a traced program at trace time).

Rules
-----

=======  ====================  ==============================================
id       name                  flags
=======  ====================  ==============================================
JXH001   prng-key-reuse        the same key variable consumed by two or more
                               ``jax.random`` sampling calls without a
                               ``split``/``fold_in`` between them
JXH002   host-sync-loop        ``float()``/``int()``/``bool()`` of a
                               subscripted value, or ``.item()``, inside a
                               Python loop or comprehension — one host
                               transfer per element when the value is a
                               device array
JXH003   static-argnames       ``static_argnames`` naming a parameter the
                               jitted function does not have, or a jitted
                               function with a bool/str-default parameter
                               (almost always meant to be static) not listed
                               in ``static_argnames``
JXH004   mutable-default       mutable default argument values
JXH005   env-query-in-jit      ``jax.devices()`` / ``jax.default_backend()``
                               (directly or through a module-local helper)
                               inside a jit-decorated function — the answer
                               is baked into the cached program at trace time
                               and is NOT part of the compilation cache key
PYL001   unused-import         module-level import never referenced
                               (``__init__.py`` re-export files are exempt)
PYL002   shadowed-builtin      a parameter or assignment shadowing a python
                               builtin
=======  ====================  ==============================================

Suppression: append ``# repro-lint: disable=RULE[,RULE...]`` to the flagged
line (``disable=all`` silences every rule there).  Always pair a suppression
with a justification comment — the analyzer treats an unexplained suppression
as reviewer-hostile, even though it cannot reject it.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.report import Violation

# files scanned by default (relative to the repo root)
DEFAULT_PATHS: Tuple[str, ...] = ("src",)
# frozen-verbatim legacy anchors are exempt from every rule
DEFAULT_EXCLUDE: Tuple[str, ...] = ("_legacy_simulator.py",)

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\s]+)")

# jax.random callables that *derive* keys rather than consuming entropy
_KEY_DERIVERS = {"split", "fold_in", "PRNGKey", "key", "clone", "key_data", "wrap_key_data"}
# module paths recognized as jax.random
_RANDOM_PREFIXES = {("jax", "random"), ("random",), ("jrandom",), ("jr",)}

_ENV_QUERIES = {
    ("jax", "devices"),
    ("jax", "local_devices"),
    ("jax", "device_count"),
    ("jax", "local_device_count"),
    ("jax", "default_backend"),
}

_SHADOW_BUILTINS = {
    "list", "dict", "set", "tuple", "type", "id", "input", "filter", "map",
    "next", "format", "object", "str", "int", "float", "bool", "len", "hash",
    "iter", "round", "slice", "compile", "eval", "open", "sum", "min", "max",
    "all", "any", "vars", "dir", "range", "zip", "sorted", "enumerate",
    "bytes", "print", "property",
}


@dataclass(frozen=True)
class LintRule:
    id: str
    name: str
    description: str
    hint: str
    check: Callable[["_Module"], Iterator[Violation]]


LINT_RULES: Dict[str, LintRule] = {}


def _register(rule_id: str, name: str, description: str, hint: str):
    def deco(fn):
        LINT_RULES[rule_id] = LintRule(rule_id, name, description, hint, fn)
        return fn

    return deco


# --------------------------------------------------------------------- helpers
class _Module:
    """One parsed source file plus the per-line suppression table."""

    def __init__(self, source: str, path: str):
        self.tree = ast.parse(source, filename=path)
        self.lines = source.splitlines()
        self.path = path

    def suppressed(self, node: ast.AST) -> Set[str]:
        """Rule ids suppressed on any physical line of ``node``'s statement,
        or on the line directly above it (comment-on-its-own-line form)."""
        first = getattr(node, "lineno", None)
        if first is None:
            return set()
        last = getattr(node, "end_lineno", first) or first
        out: Set[str] = set()
        for ln in range(max(first - 1, 1), last + 1):
            if 0 < ln <= len(self.lines):
                m = _SUPPRESS_RE.search(self.lines[ln - 1])
                if m:
                    out |= {t.strip() for t in m.group(1).split(",") if t.strip()}
        return out

    def violation(self, rule: str, node: ast.AST, message: str) -> Optional[Violation]:
        sup = self.suppressed(node)
        if rule in sup or "all" in sup:
            return None
        return Violation(
            rule=rule,
            where=f"{self.path}:{getattr(node, 'lineno', 0)}",
            message=message,
            hint=LINT_RULES[rule].hint if rule in LINT_RULES else "",
        )


def _dotted(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``a.b.c`` -> ("a", "b", "c"); None for anything non-dotted."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _scopes(tree: ast.Module) -> Iterator[ast.AST]:
    """The module plus every (nested) function definition."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _scope_nodes(scope: ast.AST) -> Iterator[ast.AST]:
    """Walk a scope's own statements without descending into nested defs."""
    body = scope.body if hasattr(scope, "body") else []
    stack = list(body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            # a nested def is its own scope; class-body bindings are class
            # attributes, which shadow nothing outside the class statement
            continue
        stack.extend(ast.iter_child_nodes(node))


def _assigned_names(scope: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in _scope_nodes(scope):
        if isinstance(node, ast.Name) and isinstance(node.ctx, (ast.Store, ast.Del)):
            names.add(node.id)
    return names


def _func_params(fn: ast.AST) -> List[str]:
    a = fn.args
    params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg.arg)
    if a.kwarg:
        params.append(a.kwarg.arg)
    return params


def _is_jax_random_call(call: ast.Call) -> Optional[str]:
    dotted = _dotted(call.func)
    if not dotted or len(dotted) < 2:
        return None
    prefix, attr = tuple(dotted[:-1]), dotted[-1]
    if prefix in _RANDOM_PREFIXES:
        return attr
    return None


def _jit_decoration(fn: ast.AST) -> Optional[Tuple[bool, Optional[ast.Call]]]:
    """(is_jitted, jit_call_node_or_None) when ``fn`` is jit-decorated.

    Recognizes ``@jax.jit`` and ``@partial(jax.jit, ...)`` /
    ``@functools.partial(jax.jit, ...)``.
    """
    for dec in getattr(fn, "decorator_list", []):
        if _dotted(dec) in {("jax", "jit"), ("jit",)}:
            return True, None
        if isinstance(dec, ast.Call):
            head = _dotted(dec.func)
            if head in {("jax", "jit"), ("jit",)}:
                return True, dec
            if head in {("partial",), ("functools", "partial")} and dec.args:
                if _dotted(dec.args[0]) in {("jax", "jit"), ("jit",)}:
                    return True, dec
    return None


def _static_argnames_literal(call: Optional[ast.Call]) -> Optional[List[str]]:
    """The literal static_argnames of a jit/partial call, None if absent or
    not a literal we can read."""
    if call is None:
        return None
    for kw in call.keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            return [v.value]
        if isinstance(v, (ast.Tuple, ast.List)):
            names = []
            for elt in v.elts:
                if not (isinstance(elt, ast.Constant) and isinstance(elt.value, str)):
                    return None
                names.append(elt.value)
            return names
        return None
    return None


# ----------------------------------------------------------------------- rules
@_register(
    "JXH001",
    "prng-key-reuse",
    "the same PRNG key consumed by two or more jax.random sampling calls",
    "jax.random.split the key (one subkey per consumer) before fanning out; "
    "reusing a key makes the draws identical, not independent",
)
def _check_key_reuse(mod: _Module) -> Iterator[Violation]:
    for scope in _scopes(mod.tree):
        reassigned = _assigned_names(scope)
        consumed: Dict[str, ast.AST] = {}
        for node in _scope_nodes(scope):
            if not isinstance(node, ast.Call):
                continue
            sampler = _is_jax_random_call(node)
            if sampler is None or sampler in _KEY_DERIVERS:
                continue
            if not node.args or not isinstance(node.args[0], ast.Name):
                continue
            name = node.args[0].id
            if name in reassigned:
                continue  # loop-carried / re-split keys track their own path
            if name in consumed:
                v = mod.violation(
                    "JXH001",
                    node,
                    f"key {name!r} already consumed by jax.random."
                    f"{_is_jax_random_call(consumed[name])} on line "
                    f"{consumed[name].lineno}; this draw is correlated with it",
                )
                if v:
                    yield v
            else:
                consumed[name] = node


@_register(
    "JXH002",
    "host-sync-loop",
    "per-element float()/int()/.item() inside a Python loop",
    "one host transfer per element when the operand is a device array; pull "
    "the whole array once (jax.device_get / np.asarray) or vectorize with "
    "jnp.asarray(xs)[idx]",
)
def _check_host_sync_loop(mod: _Module) -> Iterator[Violation]:
    loops = [
        n
        for n in ast.walk(mod.tree)
        if isinstance(n, (ast.For, ast.While, ast.ListComp, ast.SetComp,
                          ast.DictComp, ast.GeneratorExp))
    ]
    seen: Set[int] = set()
    for loop in loops:
        for node in ast.walk(loop):
            if id(node) in seen or not isinstance(node, ast.Call):
                continue
            msg = None
            if (
                isinstance(node.func, ast.Name)
                and node.func.id in ("float", "int", "bool")
                and len(node.args) == 1
                and isinstance(node.args[0], ast.Subscript)
            ):
                msg = (
                    f"{node.func.id}() of a subscripted value inside a loop — "
                    "a device-array operand costs one host sync per element"
                )
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "item"
                and not node.args
            ):
                msg = ".item() inside a loop — one host sync per element"
            if msg:
                seen.add(id(node))
                v = mod.violation("JXH002", node, msg)
                if v:
                    yield v


@_register(
    "JXH003",
    "static-argnames",
    "static_argnames out of sync with the jitted function's signature",
    "static_argnames must name actual parameters; bool/str-default "
    "parameters of a jitted function are almost always static — list them, "
    "or they retrace as traced values (bools) / fail to hash (objects)",
)
def _check_static_argnames(mod: _Module) -> Iterator[Violation]:
    # local defs, for the jax.jit(fn_name, static_argnames=...) call form
    local_defs = {
        n.name: n
        for n in ast.walk(mod.tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }

    def check_names(call: ast.Call, fn: ast.AST) -> Iterator[Violation]:
        names = _static_argnames_literal(call)
        params = _func_params(fn)
        if names:
            for name in names:
                if name not in params:
                    v = mod.violation(
                        "JXH003",
                        call,
                        f"static_argnames names {name!r}, which is not a "
                        f"parameter of {fn.name!r} ({', '.join(params)})",
                    )
                    if v:
                        yield v
        listed = set(names or ())
        for arg, default in _defaults_of(fn):
            if arg in listed:
                continue
            if isinstance(default, ast.Constant) and isinstance(default.value, (bool, str)):
                v = mod.violation(
                    "JXH003",
                    fn,
                    f"jitted {fn.name!r} has parameter {arg!r} with a "
                    f"{type(default.value).__name__} default but it is not in "
                    "static_argnames",
                )
                if v:
                    yield v

    for fn in local_defs.values():
        jit = _jit_decoration(fn)
        if jit:
            yield from check_names(jit[1] or ast.Call(func=ast.Name(id="jit"), args=[], keywords=[]), fn)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call) or _dotted(node.func) not in {("jax", "jit"), ("jit",)}:
            continue
        if node.args and isinstance(node.args[0], ast.Name):
            fn = local_defs.get(node.args[0].id)
            if fn is not None and _jit_decoration(fn) is None:
                yield from check_names(node, fn)


def _defaults_of(fn: ast.AST) -> Iterator[Tuple[str, ast.AST]]:
    a = fn.args
    pos = a.posonlyargs + a.args
    for arg, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        yield arg.arg, default
    for arg, default in zip(a.kwonlyargs, a.kw_defaults):
        if default is not None:
            yield arg.arg, default


@_register(
    "JXH004",
    "mutable-default",
    "mutable default argument value",
    "default values are evaluated once at def time and shared across calls; "
    "use None and create the object in the body",
)
def _check_mutable_default(mod: _Module) -> Iterator[Violation]:
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for arg, default in _defaults_of(fn):
            mutable = isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(default, ast.Call)
                and isinstance(default.func, ast.Name)
                and default.func.id in ("list", "dict", "set")
            )
            if mutable:
                v = mod.violation(
                    "JXH004",
                    fn,
                    f"{fn.name!r} has a mutable default for parameter {arg!r}",
                )
                if v:
                    yield v


@_register(
    "JXH005",
    "env-query-in-jit",
    "environment query inside a jit-decorated function",
    "jax.devices()/default_backend() evaluated during trace is baked into "
    "the cached program but is NOT part of its cache key; resolve it outside "
    "the jit and pass the answer through a static argument",
)
def _check_env_query_in_jit(mod: _Module) -> Iterator[Violation]:
    # module-local helpers that answer an environment query
    helper_names: Set[str] = set()
    for fn in ast.walk(mod.tree):
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for node in _scope_nodes(fn):
                if isinstance(node, ast.Call) and _dotted(node.func) in _ENV_QUERIES:
                    helper_names.add(fn.name)
                    break

    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) or not _jit_decoration(fn):
            continue
        for node in ast.walk(fn):  # nested defs inside a jitted fn still trace
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            direct = dotted in _ENV_QUERIES
            via_helper = (
                dotted is not None and len(dotted) == 1 and dotted[0] in helper_names
            )
            if direct or via_helper:
                what = ".".join(dotted)
                v = mod.violation(
                    "JXH005",
                    node,
                    f"jitted {fn.name!r} calls {what}() during trace — the "
                    "platform answer is baked into the compiled program",
                )
                if v:
                    yield v


@_register(
    "PYL001",
    "unused-import",
    "module-level import never referenced",
    "delete it (re-exports belong in __init__.py, which this rule skips)",
)
def _check_unused_import(mod: _Module) -> Iterator[Violation]:
    if os.path.basename(mod.path) == "__init__.py":
        return
    imported: Dict[str, ast.AST] = {}
    for node in mod.tree.body:
        if isinstance(node, ast.Import):
            for alias in node.names:
                name = alias.asname or alias.name.split(".")[0]
                imported[name] = node
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for alias in node.names:
                if alias.name == "*":
                    continue
                imported[alias.asname or alias.name] = node
    used: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            used.add(node.id)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            # __all__ entries and string annotations ("Algo | None") count
            used.update(re.findall(r"\w+", node.value))
        elif isinstance(node, ast.Attribute):
            root = _dotted(node)
            if root:
                used.add(root[0])
    for name, node in imported.items():
        if name in used:
            continue
        # honor ruff/flake8-style suppression on deliberate re-exports
        lines = mod.lines[node.lineno - 1 : (node.end_lineno or node.lineno)]
        if any("# noqa" in ln for ln in lines):
            continue
        v = mod.violation("PYL001", node, f"imported name {name!r} is never used")
        if v:
            yield v


@_register(
    "PYL002",
    "shadowed-builtin",
    "parameter or assignment shadowing a python builtin",
    "rename it; shadowing len/type/id/... breaks the builtin for the rest "
    "of the scope",
)
def _check_shadowed_builtin(mod: _Module) -> Iterator[Violation]:
    for fn in ast.walk(mod.tree):
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for param in _func_params(fn):
                if param in _SHADOW_BUILTINS:
                    v = mod.violation(
                        "PYL002", fn, f"parameter {param!r} of {fn.name!r} shadows a builtin"
                    )
                    if v:
                        yield v
    for scope in _scopes(mod.tree):
        for node in _scope_nodes(scope):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                if node.id in _SHADOW_BUILTINS:
                    v = mod.violation(
                        "PYL002", node, f"assignment to {node.id!r} shadows a builtin"
                    )
                    if v:
                        yield v


# ------------------------------------------------------------------ public api
def lint_source(
    source: str, path: str = "<string>", rules: Optional[Sequence[str]] = None
) -> List[Violation]:
    """Run the (selected) lint rules over one source string."""
    mod = _Module(source, path)
    out: List[Violation] = []
    for rule_id, rule in LINT_RULES.items():
        if rules is not None and rule_id not in rules:
            continue
        out.extend(rule.check(mod))
    return sorted(out, key=lambda v: (v.where, v.rule))


def iter_python_files(paths: Iterable[str], exclude: Sequence[str] = DEFAULT_EXCLUDE):
    for path in paths:
        if os.path.isfile(path):
            yield path
            continue
        for root, _dirs, files in os.walk(path):
            for f in sorted(files):
                if f.endswith(".py") and f not in exclude:
                    yield os.path.join(root, f)


def lint_paths(
    paths: Sequence[str] = DEFAULT_PATHS,
    *,
    rules: Optional[Sequence[str]] = None,
    exclude: Sequence[str] = DEFAULT_EXCLUDE,
) -> List[Violation]:
    """Run the lint over every ``.py`` file under ``paths``."""
    out: List[Violation] = []
    for path in iter_python_files(paths, exclude):
        with open(path, "r", encoding="utf-8") as fh:
            out.extend(lint_source(fh.read(), path, rules))
    return out
