"""Declarative jaxpr contracts over the registered algorithms' traced programs.

Every registered :class:`~repro.federated.algorithms.base.FederatedAlgorithm`
gets its client step, aggregation body, and (once, shared) the serving decode
loop traced at smoke scale, and the traces are checked against structural and
cost-scaling contracts:

``restack``       no ``concatenate`` whose output shape matches a stacked
                  base-layer leaf — trace-time re-stacking of the
                  stacked-native layout (the PR-3 acceptance contract,
                  generalized from ``tests/test_stacked_layout.py``).
``dtype64``       no float64 intermediate anywhere in the traced program —
                  a silent f32→f64 promotion doubles memory and flops.
``callback``      no host-callback / infeed primitive inside a traced round
                  body — one host round-trip per round multiplies by the
                  population size.
``leaf-budget``   the client call signature has the same number of program
                  inputs at L layers and 2L layers (the O(k), L-independent
                  dispatch contract).
``flops-linear``  estimated program FLOPs scale linearly (positive slope)
                  with the STLD active fraction in gather mode.
``bytes-linear``  XLA ``cost_analysis()`` bytes-accessed scales linearly
                  with the active fraction.
``finite-guard``  every traced aggregation program must contain the
                  ``is_finite`` screening guard (``server.screen_finite``)
                  — the in-graph defense that keeps a corrupted client
                  update from poisoning the global PEFT.

FLOPs come from :func:`estimate_flops`, a scan-length-aware jaxpr walker —
XLA's own HLO cost analysis counts a ``scan`` body once regardless of trip
count, so it cannot see active-fraction scaling; bytes-accessed does scale
in the HLO accounting, so that side uses ``lower().cost_analysis()``.

Adding a rule: write a ``check_*`` function that takes a
:class:`ProgramTrace` (or :class:`ScalingCurve`) and returns
:class:`~repro.analysis.report.Violation` rows, register its id in
``CONTRACT_RULES``, and call it from :func:`check_algorithms`.  Exempting a
specific program from a rule is an ``ALLOWLIST`` entry — keyed
``"<algorithm>/<program>"`` with a justification string, never a bare pass.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis.report import Violation

FRACTIONS = (0.25, 0.5, 1.0)

# Smoke-scale trace config: tiny dims so one trace is ~a second; num_layers
# stays overridable for the leaf-budget L-doubling check.
_SMOKE_ARCH = "qwen3-1.7b"
_SMOKE_DIMS = dict(
    d_model=32, d_ff=64, num_heads=2, num_kv_heads=2, vocab_size=128,
    dtype="float32",
)


@dataclass(frozen=True)
class ContractRule:
    """One contract: id + human description + fix hint (for reports/docs)."""

    rule_id: str
    description: str
    hint: str


CONTRACT_RULES: Dict[str, ContractRule] = {
    r.rule_id: r
    for r in (
        ContractRule(
            "restack",
            "no traced concatenate may rebuild a stacked base-layer leaf",
            "keep params in the stacked-native layout end to end; stack once "
            "outside jit (see models/stacking.py), never inside a traced body",
        ),
        ContractRule(
            "dtype64",
            "no float64 intermediate in a traced program",
            "an op promoted to f64 (python float arithmetic on tracers, "
            "np.float64 constants); cast the operand to the compute dtype",
        ),
        ContractRule(
            "callback",
            "no host callback / infeed primitive inside a traced round body",
            "move the host-side work outside jit, or precompute it and pass "
            "the result in as an argument",
        ),
        ContractRule(
            "leaf-budget",
            "client dispatch signature must not scale with the layer count",
            "a per-layer list leaked into the call signature; pass the "
            "stacked (L, ...) tree instead",
        ),
        ContractRule(
            "flops-linear",
            "program FLOPs must scale linearly with the STLD active fraction",
            "a dense-over-L computation ignores the gather-mode active set; "
            "route layer work through the gathered (k, ...) stack",
        ),
        ContractRule(
            "bytes-linear",
            "bytes-accessed must scale linearly with the STLD active fraction",
            "per-layer params are touched even for dropped layers; gather "
            "the k active layers before the scan instead of masking after",
        ),
        ContractRule(
            "finite-guard",
            "traced aggregation must contain the non-finite screening guard",
            "route the aggregated tree through server.screen_finite (or an "
            "equivalent jnp.isfinite select) as the last step of the traced "
            "aggregation body",
        ),
        ContractRule(
            "uplink-callback",
            "the traced uplink path (dequantize → densify → aggregate) must "
            "not round-trip through the host",
            "a silent device_get / callback between dequantization and the "
            "reduce serializes every cohort member through host memory; keep "
            "the dequantize-and-merge pipeline inside one traced program",
        ),
    )
}

# rule id -> {"<algorithm>/<program>": justification}.  An entry exempts one
# traced program from one rule; the justification is printed with --list.
ALLOWLIST: Dict[str, Dict[str, str]] = {
    "restack": {},
    "dtype64": {},
    "callback": {},
    "finite-guard": {},
    "uplink-callback": {},
}


def allowlisted(rule_id: str, where: str) -> bool:
    return where in ALLOWLIST.get(rule_id, {})


# --------------------------------------------------------------- jaxpr walks
def walk_eqns(jaxpr) -> Iterable:
    """Yield every eqn in ``jaxpr`` (an open ``Jaxpr`` or ``ClosedJaxpr``),
    descending into pjit / scan / cond / custom-call sub-jaxprs."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for inner in _subjaxprs(eqn):
            yield from walk_eqns(inner)


def _subjaxprs(eqn):
    for v in eqn.params.values():
        for x in v if isinstance(v, (list, tuple)) else (v,):
            inner = getattr(x, "jaxpr", x)
            if hasattr(inner, "eqns"):
                yield inner


def stacking_concats(jaxpr, target_shapes) -> List:
    """Concatenate eqns whose output shape matches a stacked layer-leaf shape
    — i.e. trace-time re-stacking of the stacked-native layout."""
    targets = {tuple(s) for s in target_shapes}
    return [
        eqn
        for eqn in walk_eqns(jaxpr)
        if eqn.primitive.name == "concatenate"
        and any(tuple(ov.aval.shape) in targets for ov in eqn.outvars)
    ]


def stacked_leaf_shapes(tree) -> frozenset:
    """Shapes of the stacked layer leaves of ``tree`` (stacking it first if
    it still is a per-layer list)."""
    from repro.models import stacking

    if not stacking.is_stacked(tree):
        tree = stacking.stack_params(tree)
    return frozenset(tuple(x.shape) for x in jax.tree.leaves(tree))


# ------------------------------------------------------------ FLOP estimator
def _size(aval) -> float:
    return float(math.prod(aval.shape)) if aval.shape else 1.0


def estimate_flops(jaxpr) -> float:
    """Scan-length-aware FLOP estimate of a jaxpr.

    XLA's HLO ``cost_analysis()`` counts a ``scan`` body once regardless of
    trip count, so it cannot see gather-mode active-fraction scaling; this
    walker multiplies a scan body by its ``length``, takes the max over
    ``cond`` branches, and counts ``dot_general`` exactly
    (2 · |out| · contraction).  Elementwise / data-movement ops count one
    unit per output element — coarse, but exact enough for *linearity*
    contracts (the estimate is a fixed polynomial in the trip counts)."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            (lhs_contract, _), _ = eqn.params["dimension_numbers"]
            lhs = eqn.invars[0].aval
            contraction = 1.0
            for d in lhs_contract:
                contraction *= lhs.shape[d]
            total += 2.0 * _size(eqn.outvars[0].aval) * contraction
        elif name == "scan":
            length = eqn.params["length"]  # host-side eqn metadata, not a tracer
            total += float(length) * estimate_flops(eqn.params["jaxpr"])
        elif name == "while":
            # unknown trip count: count one iteration (a lower bound; still
            # monotone in the body cost)
            total += estimate_flops(eqn.params["body_jaxpr"])
            total += estimate_flops(eqn.params["cond_jaxpr"])
        elif name == "cond":
            total += max(
                estimate_flops(b) for b in eqn.params["branches"]
            )
        else:
            nested = list(_subjaxprs(eqn))
            if nested:
                total += sum(estimate_flops(j) for j in nested)
            else:
                total += sum(_size(ov.aval) for ov in eqn.outvars)
    return total


# ------------------------------------------------------------- trace records
@dataclass(frozen=True)
class ProgramTrace:
    """One traced program plus the metadata the structural rules need."""

    where: str                    # "<algorithm>/<program>" report key
    jaxpr: object                 # ClosedJaxpr
    stacked_shapes: frozenset     # restack targets; empty set disables
    num_inputs: int               # len(jaxpr.jaxpr.invars)


@dataclass(frozen=True)
class ScalingCurve:
    """Cost measurements of one program family across active fractions."""

    where: str
    fractions: Tuple[float, ...]
    flops: Tuple[float, ...]
    bytes_accessed: Tuple[float, ...]


def make_trace(where: str, jaxpr, stacked_shapes=frozenset()) -> ProgramTrace:
    return ProgramTrace(
        where=where,
        jaxpr=jaxpr,
        stacked_shapes=frozenset(tuple(s) for s in stacked_shapes),
        num_inputs=len(jaxpr.jaxpr.invars),
    )


# -------------------------------------------------------------- rule checks
_CALLBACK_PRIMS = {
    "pure_callback", "io_callback", "debug_callback", "host_callback_call",
    "outside_call", "infeed", "outfeed",
}


def check_trace_rules(trace: ProgramTrace) -> List[Violation]:
    """Run the structural rules (restack / dtype64 / callback) on one trace."""
    out: List[Violation] = []

    if trace.stacked_shapes and not allowlisted("restack", trace.where):
        concats = stacking_concats(trace.jaxpr, trace.stacked_shapes)
        if concats:
            shapes = sorted(
                {tuple(ov.aval.shape) for e in concats for ov in e.outvars}
            )
            out.append(
                Violation(
                    "restack", trace.where,
                    f"{len(concats)} traced concatenate(s) rebuild stacked "
                    f"layer leaves (shapes {shapes})",
                    CONTRACT_RULES["restack"].hint,
                )
            )

    if not allowlisted("dtype64", trace.where):
        f64 = np.dtype("float64")
        bad = sorted(
            {
                eqn.primitive.name
                for eqn in walk_eqns(trace.jaxpr)
                for ov in eqn.outvars
                if getattr(ov.aval, "dtype", None) == f64
            }
        )
        if bad:
            out.append(
                Violation(
                    "dtype64", trace.where,
                    f"float64 intermediates produced by: {', '.join(bad)}",
                    CONTRACT_RULES["dtype64"].hint,
                )
            )

    if not allowlisted("callback", trace.where):
        cbs = sorted(
            {
                eqn.primitive.name
                for eqn in walk_eqns(trace.jaxpr)
                if eqn.primitive.name in _CALLBACK_PRIMS
                or "callback" in eqn.primitive.name
            }
        )
        if cbs:
            out.append(
                Violation(
                    "callback", trace.where,
                    f"host callback primitive(s) in traced body: {', '.join(cbs)}",
                    CONTRACT_RULES["callback"].hint,
                )
            )
    return out


def check_finite_guard(trace: ProgramTrace) -> List[Violation]:
    """finite-guard: unlike the structural *absence* rules, this one
    requires a primitive to be *present* — at least one ``is_finite`` eqn
    (the lowering of ``jnp.isfinite`` inside ``server.screen_finite``)
    anywhere in the traced aggregation program."""
    if allowlisted("finite-guard", trace.where):
        return []
    for eqn in walk_eqns(trace.jaxpr):
        if eqn.primitive.name == "is_finite":
            return []
    return [
        Violation(
            "finite-guard", trace.where,
            "no is_finite primitive anywhere in the traced aggregation "
            "program: a non-finite client update would flow straight into "
            "the global PEFT",
            CONTRACT_RULES["finite-guard"].hint,
        )
    ]


def check_uplink(trace: ProgramTrace) -> List[Violation]:
    """uplink-callback: the dequantize→densify→aggregate program must stay
    on device end to end — any callback/infeed primitive means a host
    round-trip inside the compressed-uplink hot path."""
    if allowlisted("uplink-callback", trace.where):
        return []
    cbs = sorted(
        {
            eqn.primitive.name
            for eqn in walk_eqns(trace.jaxpr)
            if eqn.primitive.name in _CALLBACK_PRIMS
            or "callback" in eqn.primitive.name
        }
    )
    if cbs:
        return [
            Violation(
                "uplink-callback", trace.where,
                f"host round-trip between dequantize and reduce: {', '.join(cbs)}",
                CONTRACT_RULES["uplink-callback"].hint,
            )
        ]
    return []


def check_leaf_budget(trace: ProgramTrace, trace_2l: ProgramTrace) -> List[Violation]:
    """O(k) dispatch: the program input count may not grow with L."""
    if trace.num_inputs != trace_2l.num_inputs:
        return [
            Violation(
                "leaf-budget", trace.where,
                f"program inputs grow with the layer count: "
                f"{trace.num_inputs} at L vs {trace_2l.num_inputs} at 2L",
                CONTRACT_RULES["leaf-budget"].hint,
            )
        ]
    return []


def _linearity(xs: Sequence[float], ys: Sequence[float]):
    """Least-squares line through (xs, ys): (slope, max relative residual)."""
    n = len(xs)
    xm, ym = sum(xs) / n, sum(ys) / n
    sxx = sum((x - xm) ** 2 for x in xs)
    sxy = sum((x - xm) * (y - ym) for x, y in zip(xs, ys))
    slope = sxy / sxx
    intercept = ym - slope * xm
    scale = max(abs(ym), 1e-30)
    resid = max(abs(intercept + slope * x - y) for x, y in zip(xs, ys)) / scale
    return slope, resid


def check_curve(curve: ScalingCurve, *, tol: float = 0.02) -> List[Violation]:
    """flops-linear + bytes-linear: both cost measures must fit a positive-
    slope line over the active fractions within ``tol`` relative residual."""
    out: List[Violation] = []
    for rule_id, ys in (
        ("flops-linear", curve.flops),
        ("bytes-linear", curve.bytes_accessed),
    ):
        if allowlisted(rule_id, curve.where):
            continue
        slope, resid = _linearity(curve.fractions, ys)
        if slope <= 0:
            out.append(
                Violation(
                    rule_id, curve.where,
                    f"cost does not grow with the active fraction "
                    f"(slope {slope:.3g}; points {list(zip(curve.fractions, ys))})",
                    CONTRACT_RULES[rule_id].hint,
                )
            )
        elif resid > tol:
            out.append(
                Violation(
                    rule_id, curve.where,
                    f"cost is not linear in the active fraction "
                    f"(relative residual {resid:.3g} > {tol}; "
                    f"points {list(zip(curve.fractions, ys))})",
                    CONTRACT_RULES[rule_id].hint,
                )
            )
    return out


# ------------------------------------------------------- program construction
_TRAIN = None  # lazy: repro.configs import kept out of module import time
_trace_cache: Dict[tuple, object] = {}


def _train_cfg():
    global _TRAIN
    if _TRAIN is None:
        from repro.configs import TrainConfig

        _TRAIN = TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2)
    return _TRAIN


def _smoke_cfg(num_layers: int = 4):
    from repro.configs import get_config

    return get_config(_SMOKE_ARCH, smoke=True).replace(
        num_layers=num_layers, **_SMOKE_DIMS
    )


def _client_setup(num_layers, peft_method, lora_rank, stld_cfg):
    """Client fns + stacked args at smoke scale (mirrors the layout tests)."""
    from repro.configs import PEFTConfig
    from repro.core import peft as peft_lib
    from repro.federated.client import make_client_fns
    from repro.models.registry import init_params
    from repro.optim import adamw_init

    cfg = _smoke_cfg(num_layers)
    pcfg = PEFTConfig(method=peft_method, lora_rank=lora_rank, adapter_dim=4)
    fns = make_client_fns(
        cfg, pcfg, stld_cfg, _train_cfg(), stack_mode="scan", donate=False
    )
    key = jax.random.PRNGKey(0)
    base = init_params(key, cfg)
    peft = peft_lib.init_peft(key, cfg, pcfg)
    batches = {
        "tokens": jnp.zeros((2, 4, 8), dtype=jnp.int32),
        "targets": jnp.zeros((2, 4, 8), dtype=jnp.int32),
        "mask": jnp.ones((2, 4, 8), dtype=jnp.float32),
    }
    args = (
        base, peft, adamw_init(peft), batches,
        jnp.asarray(0.5, jnp.float32), key, jnp.asarray(0, jnp.int32),
    )
    return fns, base, args


def _peft_family(name: str) -> Tuple[str, int]:
    """(peft method, lora rank) the algorithm's client programs run with."""
    if name in ("fedadapter", "fedadaopt"):
        return "adapter", 2
    if name == "fedhetlora":
        return "lora", 16  # the max-rank tier's client program
    return "lora", 2


def _merge_family(name: str) -> str:
    if name == "fedhetlora":
        return "hetlora"
    if name.startswith("droppeft") and name != "droppeft_b3":
        return "ptls"
    return "fedavg"


def client_trace(peft_method, lora_rank, stld_enabled, *, num_layers=4,
                 where="client_step") -> ProgramTrace:
    """Structural trace of the jit'd local round in its configured mode."""
    from repro.configs import STLDConfig

    key = ("client", peft_method, lora_rank, stld_enabled, num_layers)
    cached = _trace_cache.get(key)
    if cached is None:
        scfg = STLDConfig(mode="cond", mean_rate=0.5, enabled=stld_enabled)
        fns, base, args = _client_setup(num_layers, peft_method, lora_rank, scfg)
        closed = jax.make_jaxpr(lambda *a: fns.local_round(*a))(*args)
        cached = (closed, stacked_leaf_shapes(base["layers"]))
        _trace_cache[key] = cached
    closed, shapes = cached
    return make_trace(where, closed, shapes)


def client_scaling_curve(peft_method, lora_rank, *, fractions=FRACTIONS,
                         num_layers=4, where="client_step") -> ScalingCurve:
    """Gather-mode cost curve: trace the local round at each static active
    count k = round(fraction · L) and measure FLOPs (jaxpr estimate) and
    bytes accessed (XLA cost analysis)."""
    from repro.configs import STLDConfig

    key = ("curve", peft_method, lora_rank, tuple(fractions), num_layers)
    cached = _trace_cache.get(key)
    if cached is None:
        scfg = STLDConfig(mode="gather", mean_rate=0.5, gather_bucket=1)
        fns, _, args = _client_setup(num_layers, peft_method, lora_rank, scfg)
        flops, nbytes = [], []
        for frac in fractions:
            k = max(1, round(frac * num_layers))
            closed = jax.make_jaxpr(
                lambda *a: fns.local_round(*a, num_active=k)
            )(*args)
            flops.append(estimate_flops(closed))
            cost = fns.local_round.lower(*args, num_active=k).cost_analysis()
            # repro-lint: disable=JXH002 — cost_analysis() is a host-side dict
            nbytes.append(float(cost["bytes accessed"]))
        cached = (tuple(flops), tuple(nbytes))
        _trace_cache[key] = cached
    flops, nbytes = cached
    return ScalingCurve(where, tuple(fractions), flops, nbytes)


def aggregation_trace(family: str, *, where="aggregate") -> ProgramTrace:
    """Trace the merge family's aggregation body over a 3-client cohort."""
    from repro.configs import PEFTConfig
    from repro.core import peft as peft_lib
    from repro.federated import server as server_lib

    key = ("agg", family)
    cached = _trace_cache.get(key)
    if cached is None:
        cfg = _smoke_cfg(4)
        prng = jax.random.PRNGKey(0)
        n = 3
        if family == "hetlora":
            ranks = (2, 4)
            clients = [
                peft_lib.init_peft(
                    prng, cfg, PEFTConfig(method="lora", lora_rank=r)
                )
                for r in ranks
            ]
            closed = jax.make_jaxpr(
                lambda *cs: server_lib.hetlora_aggregate(
                    list(cs), list(ranks), max(ranks)
                )
            )(*clients)
            shapes = stacked_leaf_shapes(clients[-1])
        else:
            pcfg = PEFTConfig(method="lora", lora_rank=2)
            gpeft = peft_lib.init_peft(prng, cfg, pcfg)
            if family == "ptls":
                cohort = jax.tree.map(
                    lambda x: jnp.stack([x] * n), gpeft
                )
                masks = np.ones((n, cfg.num_layers), dtype=bool)
                closed = jax.make_jaxpr(
                    lambda cp, gp: server_lib.ptls_aggregate(cp, masks, gp)
                )(cohort, gpeft)
            else:  # fedavg
                closed = jax.make_jaxpr(
                    lambda ts: server_lib.fedavg(ts)
                )([gpeft] * n)
            shapes = stacked_leaf_shapes(gpeft)
        cached = (closed, shapes)
        _trace_cache[key] = cached
    closed, shapes = cached
    return make_trace(where, closed, shapes)


def uplink_trace(family: str, *, where="uplink") -> ProgramTrace:
    """Trace the compressed-uplink server path for one merge family: int8
    payloads (from top-k'd deltas) in, dequantize, densify, aggregate — all
    inside one ``make_jaxpr`` so :func:`check_uplink` can prove the pipeline
    never leaves the device."""
    from repro.configs import PEFTConfig
    from repro.core import peft as peft_lib
    from repro.federated import compression as comp_lib
    from repro.federated import server as server_lib

    key = ("uplink", family)
    cached = _trace_cache.get(key)
    if cached is None:
        cfg = _smoke_cfg(4)
        prng = jax.random.PRNGKey(0)
        n = 3
        if family == "hetlora":
            ranks = (2, 4, 4)
            clients = [
                peft_lib.init_peft(
                    prng, cfg, PEFTConfig(method="lora", lora_rank=r)
                )
                for r in ranks
            ]
        else:
            gpeft = peft_lib.init_peft(
                prng, cfg, PEFTConfig(method="lora", lora_rank=2)
            )
            clients = [gpeft] * n
        wire = [
            comp_lib.quantize_int8(comp_lib.topk_sparsify(c, 0.25))
            for c in clients
        ]
        vals = [v for v, _ in wire]
        scales = [s for _, s in wire]
        if family == "hetlora":

            def fn(vals, scales):
                dense = [
                    comp_lib.dequantize_int8(v, s) for v, s in zip(vals, scales)
                ]
                return server_lib.hetlora_aggregate(dense, list(ranks), max(ranks))

            closed = jax.make_jaxpr(fn)(vals, scales)
            shapes = stacked_leaf_shapes(clients[-1])
        elif family == "ptls":
            masks = np.ones((n, cfg.num_layers), dtype=bool)

            def fn(vals, scales, gp):
                dense = [
                    comp_lib.dequantize_int8(v, s) for v, s in zip(vals, scales)
                ]
                cohort = jax.tree.map(lambda *xs: jnp.stack(xs), *dense)
                return server_lib.ptls_aggregate(cohort, masks, gp)

            closed = jax.make_jaxpr(fn)(vals, scales, clients[0])
            shapes = stacked_leaf_shapes(clients[0])
        else:  # fedavg

            def fn(vals, scales):
                dense = [
                    comp_lib.dequantize_int8(v, s) for v, s in zip(vals, scales)
                ]
                return server_lib.fedavg(dense)

            closed = jax.make_jaxpr(fn)(vals, scales)
            shapes = stacked_leaf_shapes(clients[0])
        cached = (closed, shapes)
        _trace_cache[key] = cached
    closed, shapes = cached
    return make_trace(where, closed, shapes)


def decode_trace(*, where="serving/decode", num_tokens=4) -> ProgramTrace:
    """Trace the greedy KV-cache decode loop at smoke scale (shared across
    algorithms — serving is method-independent)."""
    key = ("decode", num_tokens)
    cached = _trace_cache.get(key)
    if cached is None:
        from repro.launch.steps import make_serve_step
        from repro.models.registry import default_stack_mode, init_params
        from repro.models.transformer import init_caches
        from repro.serving.decode import generate

        cfg = _smoke_cfg(4)
        params = init_params(jax.random.PRNGKey(0), cfg)
        serve = make_serve_step(cfg, stack_mode=default_stack_mode(cfg))
        caches = init_caches(cfg, 2, 16, dtype=jnp.dtype(cfg.dtype))
        first = jnp.zeros((2, 1), dtype=jnp.int32)
        closed = jax.make_jaxpr(
            lambda p, c, t: generate(serve, p, c, t, 8, num_tokens)[0]
        )(params, caches, first)
        cached = (closed, stacked_leaf_shapes(params["layers"]))
        _trace_cache[key] = cached
    closed, shapes = cached
    return make_trace(where, closed, shapes)


def batched_decode_trace(
    *, where="serving/batched_decode", num_layers=4, num_tokens=4
) -> ProgramTrace:
    """Trace one multi-tenant batched decode step: pooled mixed-rank
    adapters (segmented gather kernel), stacked batched KV caches, per-row
    positions.  The pooled peft and the stacked caches are both O(k)-leaf
    trees, so this program must satisfy the same leaf budget as training.
    """
    key = ("batched_decode", num_layers, num_tokens)
    cached = _trace_cache.get(key)
    if cached is None:
        from repro.configs import PEFTConfig
        from repro.core import peft as peft_lib
        from repro.launch.steps import make_serve_step
        from repro.models.registry import init_params
        from repro.serving.adapters import AdapterPoolCache, AdapterRegistry
        from repro.serving.batcher import batched_caches

        cfg = _smoke_cfg(num_layers)
        prng = jax.random.PRNGKey(0)
        params = init_params(prng, cfg)
        registry = AdapterRegistry()
        for i, rank in enumerate((2, 4)):  # hetlora mixed ranks in one pool
            registry.register(
                f"client{i}",
                peft_lib.init_peft(
                    prng, cfg,
                    PEFTConfig(method="lora", lora_rank=rank, lora_targets=("q", "v")),
                ),
            )
        pool = AdapterPoolCache(registry, n_slots=2)
        peft = pool.pooled_peft(jnp.asarray([0, 1], jnp.int32))
        serve = make_serve_step(cfg, stack_mode="scan")
        caches = batched_caches(cfg, 2, 16, dtype=jnp.dtype(cfg.dtype))
        token = jnp.zeros((2, 1), dtype=jnp.int32)
        pos = jnp.zeros((2,), dtype=jnp.int32)
        closed = jax.make_jaxpr(
            lambda p, pf, t, ps, c: serve(p, t, ps, c, peft=pf)[0]
        )(params, peft, token, pos, caches)
        cached = (closed, stacked_leaf_shapes(params["layers"]))
        _trace_cache[key] = cached
    closed, shapes = cached
    return make_trace(where, closed, shapes)


# ----------------------------------------------------------------- top level
def check_algorithms(
    algorithms: Optional[Sequence[str]] = None,
    *,
    fractions: Sequence[float] = FRACTIONS,
    include_decode: bool = True,
    progress=None,
) -> List[Violation]:
    """Run every contract over every (or the named) registered algorithms.

    Traces are cached per program family (droppeft and its ablations share
    one client program), so the full registry costs a handful of traces."""
    from repro.federated import algorithms as alg_pkg

    names = list(algorithms) if algorithms else alg_pkg.registered_methods()
    violations: List[Violation] = []
    for name in names:
        if progress:
            progress(name)
        cls = alg_pkg.get_algorithm(name)
        method, rank = _peft_family(name)

        tr = client_trace(
            method, rank, cls.stld, where=f"{name}/client_step"
        )
        tr_2l = client_trace(
            method, rank, cls.stld, num_layers=8, where=f"{name}/client_step"
        )
        violations += check_trace_rules(tr)
        violations += check_leaf_budget(tr, tr_2l)
        violations += check_curve(
            client_scaling_curve(
                method, rank, fractions=tuple(fractions),
                where=f"{name}/client_step",
            )
        )
        agg_tr = aggregation_trace(_merge_family(name), where=f"{name}/aggregate")
        violations += check_trace_rules(agg_tr)
        violations += check_finite_guard(agg_tr)
        violations += check_uplink(
            uplink_trace(_merge_family(name), where=f"{name}/uplink")
        )
    if include_decode:
        if progress:
            progress("serving/decode")
        violations += check_trace_rules(decode_trace())
        if progress:
            progress("serving/batched_decode")
        btr = batched_decode_trace()
        btr_2l = batched_decode_trace(num_layers=8)
        violations += check_trace_rules(btr)
        violations += check_leaf_budget(btr, btr_2l)
    return violations
