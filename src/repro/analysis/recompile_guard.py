"""Recompilation guard: count XLA compilations and enforce per-run budgets.

A silent recompile per round — a static argument churning, a shape leaking
into a cache key — multiplies by the round count and, at population scale,
by the client count.  :class:`CompilationCounter` hooks the
``jax.monitoring`` event stream (every XLA backend compile fires one
``/jax/core/compile/backend_compile_duration`` event) so a test or the CLI
can assert a steady-state experiment compiles nothing new:

    with recompile_guard(max_compiles=0, label="droppeft rounds 3-6"):
        runner.run(rounds=6)          # rounds 0-3 already warmed the caches

:func:`check_experiment_recompiles` packages the standard check the CLI
runs: warm a smoke-scale experiment for a few rounds under a schedule
policy, then extend it and require at most the policy's budget of new
programs (0 for sync/deadline — every shape is known after round one;
async-buffer refills dispatch varying cohort sizes, so it gets a small
bounded allowance).
"""
from __future__ import annotations

import contextlib
from typing import Dict, List, Optional, Sequence

from repro.analysis.report import Violation

COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

# steady-state budget for NEW programs after a warmed-up multi-round run
DEFAULT_BUDGETS: Dict[str, int] = {
    "sync": 0,
    "deadline": 0,
    # async refills dispatch as many devices as just arrived, so late rounds
    # can still meet a cohort size (and its stack/unstack helpers) the
    # warmup never saw; bounded by the buffer-size grid, not by the rounds
    "async-buffer": 8,
}


class RecompileBudgetExceeded(RuntimeError):
    """A guarded block compiled more XLA programs than its budget."""


class CompilationCounter:
    """Context manager counting XLA backend compilations via jax.monitoring."""

    def __init__(self):
        self.count = 0

    def _listen(self, event: str, duration: float, **kw) -> None:
        if event == COMPILE_EVENT:
            self.count += 1

    def __enter__(self) -> "CompilationCounter":
        from jax import monitoring

        monitoring.register_event_duration_secs_listener(self._listen)
        return self

    def __exit__(self, *exc) -> bool:
        try:
            from jax._src import monitoring as _monitoring

            _monitoring._unregister_event_duration_listener_by_callback(
                self._listen
            )
        except Exception:
            # the private unregister helper moved; a stale listener only
            # costs a no-op callback per compile, never correctness
            pass
        return False


@contextlib.contextmanager
def recompile_guard(max_compiles: int, *, label: str = ""):
    """Assert the with-block compiles at most ``max_compiles`` XLA programs.

    Yields the live :class:`CompilationCounter` (``counter.count`` is
    readable mid-block); raises :class:`RecompileBudgetExceeded` on exit if
    the budget was blown.  Exceptions from the block propagate unchanged."""
    with CompilationCounter() as counter:
        yield counter
    if counter.count > max_compiles:
        raise RecompileBudgetExceeded(
            f"{label or 'guarded block'}: {counter.count} XLA compilation(s), "
            f"budget {max_compiles}"
        )


# ------------------------------------------------------- experiment check
def _quickstart_runner(method: str, policy: str, *, seed: int = 0):
    """A smoke-scale experiment runner matching the test-suite configs."""
    from repro import api
    from repro.configs import FederatedConfig, TrainConfig, get_config
    from repro.data import make_task

    cfg = get_config("qwen3-1.7b", smoke=True).replace(
        num_layers=4, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
        vocab_size=128, dtype="float32",
    )
    return api.build(
        method,
        cfg=cfg,
        fed_cfg=FederatedConfig(
            num_devices=5, devices_per_round=3, local_steps=2, batch_size=8
        ),
        train_cfg=TrainConfig(
            learning_rate=5e-3, total_steps=100, warmup_steps=2
        ),
        task=make_task(num_examples=256, vocab_size=128, seed=0),
        schedule=policy,
        seed=seed,
    )


def check_experiment_recompiles(
    method: str = "droppeft",
    policies: Sequence[str] = ("sync",),
    *,
    warmup_rounds: int = 3,
    extra_rounds: int = 3,
    budgets: Optional[Dict[str, int]] = None,
    progress=None,
) -> List[Violation]:
    """Warm a multi-round experiment per policy, extend it, and require at
    most the policy's budget of newly compiled programs."""
    budgets = dict(DEFAULT_BUDGETS, **(budgets or {}))
    violations: List[Violation] = []
    for policy in policies:
        if progress:
            progress(f"{method}/{policy}")
        runner = _quickstart_runner(method, policy)
        runner.run(rounds=warmup_rounds)  # compiles every steady-state program
        with CompilationCounter() as counter:
            runner.run(rounds=warmup_rounds + extra_rounds)
        if counter.count > budgets[policy]:
            violations.append(
                Violation(
                    "recompile",
                    f"{method}/{policy}",
                    f"{counter.count} XLA compilation(s) in rounds "
                    f"{warmup_rounds}..{warmup_rounds + extra_rounds} "
                    f"(budget {budgets[policy]}) — a shape or static arg is "
                    "churning per round",
                    "make the varying value a traced argument, or bucket it "
                    "so the set of compiled programs is bounded",
                )
            )
    return violations
