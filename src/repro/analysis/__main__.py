"""CLI driver: run every analysis pass, exit nonzero on violations.

    PYTHONPATH=src python -m repro.analysis              # lint + contracts + recompile(sync)
    PYTHONPATH=src python -m repro.analysis --full       # recompile across all schedule policies
    PYTHONPATH=src python -m repro.analysis --self-test  # every negative fixture must be caught
    PYTHONPATH=src python -m repro.analysis --fixture restack   # nonzero iff the rule fires
    PYTHONPATH=src python -m repro.analysis --list       # rule catalog + allowlist
"""
from __future__ import annotations

import argparse
import sys
import time


def _progress(label: str) -> None:
    print(f"  .. {label}", flush=True)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr contracts + JAX-hazard lint + recompilation guard",
    )
    parser.add_argument("--skip-lint", action="store_true")
    parser.add_argument("--skip-contracts", action="store_true")
    parser.add_argument("--skip-recompile", action="store_true")
    parser.add_argument(
        "--full", action="store_true",
        help="recompile check under every schedule policy (default: sync only)",
    )
    parser.add_argument(
        "--algorithms", nargs="*", default=None,
        help="restrict the contract pass to these registered methods",
    )
    parser.add_argument(
        "--paths", nargs="*", default=None,
        help="lint these paths instead of the default (src)",
    )
    parser.add_argument(
        "--fixture", metavar="RULE",
        help="run one negative fixture; exit 1 when the analyzer catches it "
        "(expected), 2 when it does not (an analyzer bug)",
    )
    parser.add_argument(
        "--self-test", action="store_true",
        help="run every negative fixture; exit 0 iff all are caught",
    )
    parser.add_argument(
        "--list", action="store_true", help="list rules and allowlist entries"
    )
    args = parser.parse_args(argv)

    from repro.analysis import jaxpr_contracts, lint_jax
    from repro.analysis.report import render_report

    if args.list:
        print("== lint rules ==")
        for rule in lint_jax.LINT_RULES.values():
            print(f"  {rule.id}  {rule.name}: {rule.description}")
        print("== contract rules ==")
        for crule in jaxpr_contracts.CONTRACT_RULES.values():
            print(f"  {crule.rule_id}: {crule.description}")
        print("  recompile: steady-state runs must not compile new programs")
        print("== contract allowlist ==")
        entries = [
            (rule_id, where, why)
            for rule_id, m in jaxpr_contracts.ALLOWLIST.items()
            for where, why in m.items()
        ]
        for rule_id, where, why in entries or []:
            print(f"  {rule_id} @ {where}: {why}")
        if not entries:
            print("  (empty)")
        return 0

    if args.fixture or args.self_test:
        from repro.analysis import fixtures

        if args.self_test:
            results = fixtures.self_test()
            width = max(len(r) for r in results)
            for rule_id, caught in results.items():
                print(f"  {rule_id:{width}s}  {'caught' if caught else 'MISSED'}")
            missed = [r for r, ok in results.items() if not ok]
            if missed:
                print(f"self-test FAILED: fixtures not caught: {missed}")
                return 2
            print(f"self-test OK: all {len(results)} fixtures caught")
            return 0
        try:
            found = fixtures.run_fixture(args.fixture)
        except KeyError:
            print(f"unknown fixture {args.fixture!r}; one of "
                  f"{sorted(fixtures.FIXTURES)}")
            return 2
        print(render_report(found, title=f"fixture {args.fixture}"))
        if any(v.rule == args.fixture for v in found):
            return 1  # the analyzer caught the planted bug: expected
        print(f"fixture {args.fixture!r} NOT caught — analyzer regression")
        return 2

    failed = False
    t0 = time.time()

    if not args.skip_lint:
        violations = lint_jax.lint_paths(
            tuple(args.paths) if args.paths else lint_jax.DEFAULT_PATHS
        )
        print(render_report(violations, title="lint"))
        failed |= bool(violations)

    if not args.skip_contracts:
        print("jaxpr contracts:", flush=True)
        violations = jaxpr_contracts.check_algorithms(
            args.algorithms, progress=_progress
        )
        print(render_report(violations, title="jaxpr contracts"))
        failed |= bool(violations)

    if not args.skip_recompile:
        from repro.analysis.recompile_guard import check_experiment_recompiles

        policies = ("sync", "deadline", "async-buffer") if args.full else ("sync",)
        print("recompile guard:", flush=True)
        violations = check_experiment_recompiles(
            policies=policies, progress=_progress
        )
        print(render_report(violations, title="recompile guard"))
        failed |= bool(violations)

    status = "FAILED" if failed else "OK"
    print(f"analysis {status} in {time.time() - t0:.1f}s")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
