"""Negative fixtures: one deliberately-violating toy program per rule.

Each fixture runs the real analyzer machinery (never a stub) over a program
built to violate exactly one rule and returns the violations found, so

* ``python -m repro.analysis --fixture RULE`` exits nonzero — proof the
  analyzer catches that class of bug, and
* ``python -m repro.analysis --self-test`` asserts every fixture is caught —
  proof a refactor of the analyzer didn't silently blind a rule.

The fixtures are the analyzer's own regression suite; the pytest coverage in
``tests/test_analysis_*.py`` drives them through this module.
"""
from __future__ import annotations

import textwrap
from functools import partial
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_contracts as contracts
from repro.analysis import lint_jax
from repro.analysis.recompile_guard import CompilationCounter
from repro.analysis.report import Violation

FIXTURES: Dict[str, Callable[[], List[Violation]]] = {}


def _fixture(rule_id: str):
    def deco(fn):
        FIXTURES[rule_id] = fn
        return fn

    return deco


def _lint(source: str) -> List[Violation]:
    return lint_jax.lint_source(textwrap.dedent(source), "fixture.py")


# ------------------------------------------------------------- lint fixtures
@_fixture("JXH001")
def key_reuse() -> List[Violation]:
    return _lint(
        """
        import jax

        def two_draws(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b
        """
    )


@_fixture("JXH002")
def host_sync_loop() -> List[Violation]:
    return _lint(
        """
        def pull(rates, pos):
            return [float(rates[i]) for i in pos]
        """
    )


@_fixture("JXH003")
def stale_static_argnames() -> List[Violation]:
    return _lint(
        """
        from functools import partial

        import jax

        @partial(jax.jit, static_argnames=("mode",))
        def f(x):
            return x * 2
        """
    )


@_fixture("JXH004")
def mutable_default() -> List[Violation]:
    return _lint(
        """
        def accumulate(x, acc=[]):
            acc.append(x)
            return acc
        """
    )


@_fixture("JXH005")
def env_query_in_jit() -> List[Violation]:
    return _lint(
        """
        import jax

        @jax.jit
        def f(x):
            if jax.devices()[0].platform == "cpu":
                return x
            return x * 2
        """
    )


@_fixture("PYL001")
def unused_import() -> List[Violation]:
    return _lint(
        """
        import os

        def f():
            return 1
        """
    )


@_fixture("PYL002")
def shadowed_builtin() -> List[Violation]:
    return _lint(
        """
        def head(list):
            return list[0]
        """
    )


# --------------------------------------------------------- contract fixtures
@_fixture("restack")
def traced_restack() -> List[Violation]:
    """A per-layer list stacked INSIDE the traced program — the layout bug
    the stacked-native refactor removed."""
    num_layers, d = 4, 8
    layers = [jnp.zeros((d,)) for _ in range(num_layers)]

    def f(ls):
        stacked = jnp.stack(ls)  # (L, d) rebuilt at trace time
        return jnp.sum(stacked * 2.0)

    closed = jax.make_jaxpr(f)(layers)
    trace = contracts.make_trace("fixture/restack", closed, {(num_layers, d)})
    return contracts.check_trace_rules(trace)


@_fixture("dtype64")
def silent_f64() -> List[Violation]:
    """An f32 input promoted to f64 mid-program (x64 mode makes the
    promotion representable, exactly as a production x64 run would)."""
    from jax.experimental import enable_x64

    with enable_x64():
        closed = jax.make_jaxpr(
            lambda x: jnp.sum(x.astype(jnp.float64) * 2.0)
        )(jnp.zeros((4,), jnp.float32))
    trace = contracts.make_trace("fixture/dtype64", closed)
    return contracts.check_trace_rules(trace)


@_fixture("callback")
def host_callback_in_body() -> List[Violation]:
    """A pure_callback smuggled into a traced body — one host round-trip per
    execution."""
    import numpy as np

    def f(x):
        y = jax.pure_callback(
            lambda v: np.asarray(v) * 2.0,
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            x,
        )
        return jnp.sum(y)

    closed = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.float32))
    trace = contracts.make_trace("fixture/callback", closed)
    return contracts.check_trace_rules(trace)


@_fixture("leaf-budget")
def per_layer_signature() -> List[Violation]:
    """A client signature that takes one argument per layer — the O(L·k)
    dispatch shape the stacked layout retired."""

    def trace(num_layers):
        layers = [jnp.zeros((8,)) for _ in range(num_layers)]
        closed = jax.make_jaxpr(lambda ls: sum(ls) * 2.0)(layers)
        return contracts.make_trace("fixture/leaf-budget", closed)

    return contracts.check_leaf_budget(trace(4), trace(8))


def _flat_cost_curve() -> contracts.ScalingCurve:
    """A fake gather-mode program that runs dense over ALL layers and only
    pretends to honor the static active count — its cost curve is flat."""
    num_layers, d = 4, 16
    weights = jnp.ones((num_layers, d, d), jnp.float32)
    x = jnp.ones((d,), jnp.float32)

    @partial(jax.jit, static_argnames=("k",))
    def f(x, weights, k: int):
        def body(h, w):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, weights)  # k never gathers anything
        return h

    flops, nbytes = [], []
    for frac in contracts.FRACTIONS:
        k = max(1, round(frac * num_layers))
        closed = jax.make_jaxpr(lambda x, w: f(x, w, k=k))(x, weights)
        flops.append(contracts.estimate_flops(closed))
        cost = f.lower(x, weights, k=k).cost_analysis()
        # repro-lint: disable=JXH002 — cost_analysis() is a host-side dict
        nbytes.append(float(cost["bytes accessed"]))
    return contracts.ScalingCurve(
        "fixture/flat-cost", contracts.FRACTIONS, tuple(flops), tuple(nbytes)
    )


@_fixture("flops-linear")
def flat_flops() -> List[Violation]:
    return [
        v for v in contracts.check_curve(_flat_cost_curve())
        if v.rule == "flops-linear"
    ]


@_fixture("bytes-linear")
def flat_bytes() -> List[Violation]:
    return [
        v for v in contracts.check_curve(_flat_cost_curve())
        if v.rule == "bytes-linear"
    ]


@_fixture("finite-guard")
def unguarded_aggregation() -> List[Violation]:
    """An aggregation body with the screening guard deleted — a NaN client
    update would average straight into the global PEFT."""
    n, d = 3, 8
    clients = [{"a": jnp.ones((d,)), "b": jnp.ones((d,))} for _ in range(n)]

    def naive_fedavg(trees):
        return jax.tree.map(lambda *xs: sum(xs) / len(xs), *trees)

    closed = jax.make_jaxpr(naive_fedavg)(clients)
    trace = contracts.make_trace("fixture/finite-guard", closed)
    return contracts.check_finite_guard(trace)


@_fixture("uplink-callback")
def host_roundtrip_in_uplink() -> List[Violation]:
    """A dequantize→aggregate pipeline with a pure_callback wedged between
    the two — the silent device_get the uplink contract forbids."""
    import numpy as np

    from repro.federated import compression as comp_lib

    n, d = 3, 8
    clients = [{"a": jnp.ones((d,)), "b": jnp.ones((d,))} for _ in range(n)]
    wire = [comp_lib.quantize_int8(c) for c in clients]
    vals = [v for v, _ in wire]
    scales = [s for _, s in wire]

    def fn(vals, scales):
        dense = [comp_lib.dequantize_int8(v, s) for v, s in zip(vals, scales)]
        # the host round-trip: every reconstructed tree bounces off numpy
        dense = [
            jax.tree.map(
                lambda x: jax.pure_callback(
                    lambda v: np.asarray(v), jax.ShapeDtypeStruct(x.shape, x.dtype), x
                ),
                t,
            )
            for t in dense
        ]
        return jax.tree.map(lambda *xs: sum(xs) / len(xs), *dense)

    closed = jax.make_jaxpr(fn)(vals, scales)
    trace = contracts.make_trace("fixture/uplink-callback", closed)
    return contracts.check_uplink(trace)


# -------------------------------------------------------- recompile fixture
@_fixture("recompile")
def static_arg_churn() -> List[Violation]:
    """A static argument fed a fresh value per call: one XLA compile each."""
    f = jax.jit(lambda x, s: x + s, static_argnums=(1,))
    with CompilationCounter() as counter:
        for s in range(5):
            f(jnp.float32(1.0), 100 + s)  # offset: never collides with cache
    if counter.count > 1:
        return [
            Violation(
                "recompile",
                "fixture/static-arg-churn",
                f"{counter.count} XLA compilation(s) for 5 calls varying one "
                "static arg (budget 1)",
                "make the varying value a traced argument, or bucket it so "
                "the set of compiled programs is bounded",
            )
        ]
    return []


def run_fixture(rule_id: str) -> List[Violation]:
    """Run one fixture; raises KeyError for an unknown rule id."""
    return FIXTURES[rule_id]()


def self_test() -> Dict[str, bool]:
    """rule id -> was the deliberately-bad program caught by that rule?"""
    return {
        rule_id: any(v.rule == rule_id for v in fn())
        for rule_id, fn in FIXTURES.items()
    }
