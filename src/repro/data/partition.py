"""Non-IID Dirichlet partitioning across federated devices (paper §6.1).

Key discipline: partitioning is host-side and seeded, never keyed — it uses
one ``np.random.default_rng(seed)`` Generator per call and consumes no JAX
PRNG keys, so the device data split is a pure function of ``(labels, seed)``
and is identical across cohort modes, schedulers, and restarts.  Keep it
that way: threading a ``jax.random`` key through here would couple the data
partition to the training stream and silently change every downstream draw.
"""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(
    labels: np.ndarray,
    num_devices: int,
    alpha: float,
    *,
    min_per_device: int = 8,
    seed: int = 0,
) -> List[np.ndarray]:
    """Split example indices across devices with Dir(alpha) label skew.

    Lower alpha -> stronger label-distribution shift (paper Fig. 15).
    Guarantees every device at least ``min_per_device`` examples by
    re-drawing the allocation when violated (up to 100 attempts).
    """
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(num_classes)]
    for attempt in range(100):
        device_idx: List[list] = [[] for _ in range(num_devices)]
        for c in range(num_classes):
            idx = idx_by_class[c].copy()
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(num_devices, alpha))
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for dev, part in enumerate(np.split(idx, cuts)):
                device_idx[dev].extend(part.tolist())
        sizes = [len(d) for d in device_idx]
        if min(sizes) >= min_per_device:
            break
    out = []
    for d in device_idx:
        arr = np.array(sorted(d), dtype=np.int64)
        if len(arr) < min_per_device:  # pathological alpha: top up uniformly
            extra = rng.integers(0, len(labels), size=min_per_device - len(arr))
            arr = np.concatenate([arr, extra])
        out.append(arr)
    return out
