"""Synthetic classification-as-LM tasks (MNLI/QQP/AGNews stand-ins).

The container is offline, so the paper's GLUE datasets are replaced by a
*planted-pattern* sequence classification task with controllable difficulty:

* each class c has a signature token subset; a fraction ``signal`` of the
  sequence tokens is drawn from the class subset, the rest uniformly;
* the model is trained as a causal LM that must emit the class's label token
  at the final position (prompt ends with a fixed [CLS]-like query token);
* accuracy = argmax over the ``num_classes`` label-token logits at that
  position — the natural analogue of the paper's classification accuracy.

This keeps every architecture path (LM head, decoder stacks) identical to
real fine-tuning while giving a learnable, partitionable labelled dataset.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SyntheticTask:
    name: str
    vocab_size: int
    seq_len: int
    num_classes: int
    tokens: np.ndarray  # (N, seq_len) int32; last position is the query token
    labels: np.ndarray  # (N,) int32 class ids

    @property
    def label_tokens(self) -> np.ndarray:
        # label token for class c is (1 + c); token 0 is the query token
        return np.arange(1, self.num_classes + 1)

    def lm_batch(self, idx: np.ndarray):
        """Inputs/labels for the LM objective: predict the label token at the
        final position; other positions are next-token (masked out)."""
        toks = self.tokens[idx]
        labels = self.labels[idx]
        inputs = toks
        targets = np.concatenate([toks[:, 1:], np.zeros((len(idx), 1), np.int32)], axis=1)
        targets[:, -1] = 1 + labels
        mask = np.zeros_like(targets, dtype=np.float32)
        mask[:, -1] = 1.0
        return {
            "tokens": inputs.astype(np.int32),
            "targets": targets.astype(np.int32),
            "mask": mask,
            "labels": labels.astype(np.int32),
        }


def make_task(
    name: str = "mnli-syn",
    *,
    num_examples: int = 4096,
    vocab_size: int = 512,
    seq_len: int = 32,
    num_classes: int = 4,
    signal: float = 0.35,
    seed: int = 0,
) -> SyntheticTask:
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, num_classes, size=num_examples).astype(np.int32)
    # class signatures: disjoint token ranges in the upper half of the vocab
    half = vocab_size // 2
    sig_width = max(1, half // num_classes)
    tokens = rng.integers(
        1 + num_classes, vocab_size, size=(num_examples, seq_len)
    ).astype(np.int32)
    n_signal = max(1, int(signal * (seq_len - 1)))
    for i in range(num_examples):
        c = labels[i]
        lo = half + c * sig_width
        hi = min(vocab_size, lo + sig_width)
        pos = rng.choice(seq_len - 1, size=n_signal, replace=False)
        tokens[i, pos] = rng.integers(lo, hi, size=n_signal)
    tokens[:, -1] = 0  # query token
    return SyntheticTask(name, vocab_size, seq_len, num_classes, tokens, labels)
