"""Per-device data pipeline: shuffled epoch batching + train/val split."""
from __future__ import annotations

from typing import Iterator

import numpy as np


class DeviceDataset:
    """One federated device's local shard with train/val split."""

    def __init__(self, task, indices: np.ndarray, *, val_fraction: float = 0.2, seed: int = 0):
        self.task = task
        rng = np.random.default_rng(seed)
        idx = indices.copy()
        rng.shuffle(idx)
        n_val = max(1, int(len(idx) * val_fraction))
        self.val_idx = idx[:n_val]
        self.train_idx = idx[n_val:] if len(idx) > n_val else idx
        self._rng = rng

    def train_batches(self, batch_size: int, num_batches: int) -> Iterator[dict]:
        for _ in range(num_batches):
            # fixed batch size (sampling with replacement on small shards) so
            # every device hits the same jit signature
            take = self._rng.choice(
                self.train_idx, size=batch_size, replace=len(self.train_idx) < batch_size
            )
            yield self.task.lm_batch(take)

    def val_batch(self, max_examples: int = 64) -> dict:
        take = self.val_idx[:max_examples]
        return self.task.lm_batch(take)

    def __len__(self):
        return len(self.train_idx)
