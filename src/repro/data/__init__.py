from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SyntheticTask, make_task
from repro.data.pipeline import DeviceDataset

__all__ = ["dirichlet_partition", "SyntheticTask", "make_task", "DeviceDataset"]
