"""Update compression for the device→server uplink (related-work axis:
gradient sparsification / quantization in FL [paper §7]).

DropPEFT already shrinks uploads structurally (PEFT modules × PTLS layer
masks); these are the orthogonal bit-level compressors stacked on top, now
first-class in the round loop: the algorithm's ``compress_uplink`` hook
compresses each device's PEFT *delta*, :class:`ErrorFeedback` residuals ride
:class:`~repro.federated.state.RoundState`, and ``SystemModel`` bills the
compressed wire sizes so virtual-clock comm time shrinks.

* ``quantize_int8`` / ``dequantize_int8`` — per-leaf symmetric int8 with a
  fp32 scale.  Honest ratio: a leaf of ``n`` fp32 entries costs ``n + 4``
  bytes on the wire (values + one scale), so the ratio is ``4n / (n + 4)``
  — asymptotically 4x, but only 2x at n = 4 and *worse than fp32* below
  n = 2.  The previously advertised flat "4.06x over fp32" ignored the
  scale overhead at small leaf sizes.
* ``topk_sparsify`` — exact-k magnitude sparsification per leaf via
  ``jax.lax.top_k`` (deterministic tie-break: equal magnitudes keep the
  lowest flat index).  ``k = max(1, floor(fraction · n + 0.5))`` — the
  requested fraction rounds half-up, with a documented ``k >= 1`` floor.
* ``ErrorFeedback`` — residual accumulation so repeated lossy uploads stay
  unbiased over rounds (Seide et al. / EF-SGD semantics).  ``ef_step`` is
  the jitted compress-decompress round-trip with a configurable residual
  decay for staleness-weighted (FedBuff-style) aggregation paths.

Wire-format byte accounting (``compressed_bytes``; per leaf of n entries,
k = top-k count, indices int32, scales fp32):

    none       4n
    int8       n + 4
    topk       8k            (4k indices + 4k fp32 values)
    int8+topk  5k + 4        (4k indices + k int8 values + 1 scale)

``serialize_compressed`` materializes exactly those buffers host-side, so a
test can cross-check the accounting against real serialized sizes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace as dc_replace
from functools import partial
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

# Compression levels, in increasing-aggressiveness order.  This tuple is the
# joint-bandit arm axis (see core.configurator.JointConfigurator).
LEVELS = ("none", "int8", "topk", "int8+topk")


@dataclass(frozen=True)
class CompressionConfig:
    """How a client compresses its PEFT delta on the uplink.

    ``kind`` is one of :data:`LEVELS`; ``tune=True`` hands the level to the
    joint (dropout rate × compression level) bandit instead of fixing it —
    ``kind`` then only names the level used for non-bandit methods.
    ``ef_decay`` scales the carried residual each round (1.0 = classic
    EF-SGD; < 1 decays stale error, the correction for staleness-weighted
    aggregation paths where old residuals are down-weighted anyway).
    """

    kind: str = "int8+topk"
    topk_fraction: float = 0.1
    error_feedback: bool = True
    ef_decay: float = 1.0
    tune: bool = False

    def __post_init__(self):
        if self.kind not in LEVELS:
            raise ValueError(
                f"unknown compression kind {self.kind!r}; one of {LEVELS}"
            )
        if not 0.0 < self.topk_fraction <= 1.0:
            raise ValueError(
                f"topk_fraction must be in (0, 1], got {self.topk_fraction}"
            )
        if not 0.0 <= self.ef_decay <= 1.0:
            raise ValueError(f"ef_decay must be in [0, 1], got {self.ef_decay}")


def resolve_compression(spec, **overrides) -> Optional[CompressionConfig]:
    """Normalize a level name / "auto" / dict / config / None, applying any
    non-None keyword overrides (``topk_fraction``, ``error_feedback``,
    ``ef_decay``).

    ``None`` means *no compression machinery at all* (the pre-compression
    bit-exact path); overrides without a spec raise instead of silently
    doing nothing.  ``"auto"`` enables the joint bandit over every level.
    """
    kw = {k: v for k, v in overrides.items() if v is not None}
    if spec is None:
        if kw:
            raise ValueError(
                f"compression options {sorted(kw)} have no effect without "
                "compression=; pass a level name, 'auto', or a "
                "CompressionConfig"
            )
        return None
    if isinstance(spec, CompressionConfig):
        cfg = spec
    elif isinstance(spec, str):
        if spec == "auto":
            cfg = CompressionConfig(tune=True)
        else:
            cfg = CompressionConfig(kind=spec)
    elif isinstance(spec, dict):
        cfg = CompressionConfig(**spec)
    else:
        raise TypeError(
            f"compression must be a level name, 'auto', a dict, or a "
            f"CompressionConfig, got {spec!r}"
        )
    return dc_replace(cfg, **kw) if kw else cfg


# ------------------------------------------------------------------ kernels
def topk_k(n: int, fraction: float) -> int:
    """Entries kept per leaf of ``n``: round half-up, floor at 1.

    Shared by the sparsifier and the byte accounting so the two can never
    disagree about k (the old ``int(fraction * n)`` truncation undercounted
    — fraction 0.25 of 10 entries kept 2, not the nearer 3)."""
    return max(1, int(math.floor(fraction * n + 0.5)))


def quantize_int8(tree) -> Tuple[object, object]:
    """pytree -> (int8 tree, fp32 scale tree).  Symmetric per-leaf.

    Returns two trees of the *input's* structure (transposed, not
    tuple-packed): the old implementation mapped each leaf to a
    ``(vals, scale)`` tuple and re-mapped with ``is_leaf=isinstance(t,
    tuple)``, which miscollapsed any pytree legitimately containing tuple
    nodes (the stacked hetlora trees do)."""
    leaves, treedef = jax.tree.flatten(tree)
    vals, scales = [], []
    for x in leaves:
        xf = x.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
        vals.append(jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8))
        scales.append(scale)
    return jax.tree.unflatten(treedef, vals), jax.tree.unflatten(treedef, scales)


def dequantize_int8(vals, scales, dtype=jnp.float32):
    return jax.tree.map(lambda v, s: (v.astype(jnp.float32) * s).astype(dtype), vals, scales)


def topk_sparsify(tree, fraction: float):
    """Keep exactly ``topk_k(n, fraction)`` entries by magnitude per leaf.

    ``jax.lax.top_k`` gives exact-k semantics with a deterministic
    tie-break (equal magnitudes keep the lowest flat index); the old
    ``jnp.sort`` + ``>= thresh`` selection kept *every* entry tied at the
    threshold, silently exceeding k and breaking the byte model."""

    def sp(x):
        xf = x.astype(jnp.float32)
        flat = xf.reshape(-1)
        n = flat.shape[0]
        k = topk_k(n, fraction)
        if k >= n:
            return x
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        mask = jnp.zeros((n,), dtype=bool).at[idx].set(True)
        return jnp.where(mask, flat, 0.0).reshape(x.shape).astype(x.dtype)

    return jax.tree.map(sp, tree)


@partial(jax.jit, static_argnames=("kind", "fraction"))
def compress_decompress(tree, *, kind: str, fraction: float = 0.1):
    """The lossy uplink round-trip as the server reconstructs it: sparsify
    (top-k), then quantize-dequantize (int8) — one jit'd dispatch per
    (kind, fraction, tree signature).  ``kind="none"`` is the identity."""
    if "topk" in kind:
        tree = topk_sparsify(tree, fraction)
    if "int8" in kind:
        vals, scales = quantize_int8(tree)
        tree = dequantize_int8(vals, scales)
    return tree


@partial(jax.jit, static_argnames=("kind", "fraction", "decay"))
def ef_step(update, residual, *, kind: str, fraction: float = 0.1,
            decay: float = 1.0):
    """One error-feedback round: compress ``update + decay · residual``,
    carry the compression error.  Returns ``(sent, new_residual)`` where
    ``sent`` is the dense server-side reconstruction."""
    corrected = jax.tree.map(
        lambda x, r: x.astype(jnp.float32) + decay * r, update, residual
    )
    sent = compress_decompress(corrected, kind=kind, fraction=fraction)
    new_residual = jax.tree.map(
        lambda c, s: c - s.astype(jnp.float32), corrected, sent
    )
    return sent, new_residual


# ------------------------------------------------------------- wire format
def compressed_bytes(tree, config="int8+topk") -> int:
    """Uplink bytes after compression, matching the wire format exactly.

    Per leaf of ``n`` entries (k = ``topk_k(n, fraction)``): ``none`` ships
    4n fp32 bytes; ``int8`` ships n value bytes + one 4-byte scale;
    ``topk`` ships k int32 indices + k fp32 values; ``int8+topk`` ships k
    int32 indices + k int8 values + one scale.  Scales exist only on int8
    paths (the old accounting billed them even for fp32 payloads), and k is
    computed per leaf (a single global ``int(n · sparsity)`` both truncated
    and ignored the per-leaf ``k >= 1`` floor)."""
    cfg = resolve_compression(config)
    if cfg is None:
        cfg = CompressionConfig(kind="none")
    total = 0
    for x in jax.tree.leaves(tree):
        n = int(np.prod(np.shape(x))) if np.shape(x) else 1
        if cfg.kind == "none":
            total += 4 * n
        elif cfg.kind == "int8":
            total += n + 4
        else:
            k = min(topk_k(n, cfg.topk_fraction), n)
            if cfg.kind == "topk":
                total += 8 * k
            else:  # int8+topk
                total += 5 * k + 4
    return total


def serialize_compressed(tree, config="int8+topk") -> list:
    """Host-side wire buffers (numpy) for every leaf, in the exact format
    :func:`compressed_bytes` accounts for — ``sum(b.nbytes)`` over the
    returned list equals the accounting.  Test/debug aid, not a hot path."""
    cfg = resolve_compression(config)
    if cfg is None:
        cfg = CompressionConfig(kind="none")
    buffers = []
    for x in jax.tree.leaves(tree):
        flat = np.asarray(x, dtype=np.float32).reshape(-1)
        n = flat.size
        if cfg.kind == "none":
            buffers.append(flat)
            continue
        if "topk" in cfg.kind:
            k = min(topk_k(n, cfg.topk_fraction), n)
            # argsort on (-|x|, index) reproduces lax.top_k's tie-break
            order = np.lexsort((np.arange(n), -np.abs(flat)))[:k]
            idx = np.sort(order).astype(np.int32)
            vals = flat[idx]
            buffers.append(idx)
        else:
            vals = flat
        if "int8" in cfg.kind:
            scale = max(float(np.max(np.abs(vals))) if vals.size else 0.0, 1e-12) / 127.0
            q = np.clip(np.round(vals / scale), -127, 127).astype(np.int8)
            buffers.append(q)
            buffers.append(np.float32(scale).reshape(1))
        else:
            buffers.append(vals.astype(np.float32))
    return buffers


def uplink_ratio(tree, config) -> float:
    """Compressed / fp32 uplink size for ``tree`` — the per-device factor
    the :class:`~repro.federated.system_model.SystemModel` multiplies into
    its uplink traffic (1.0 = uncompressed, bit-exact billing)."""
    n = sum(
        (int(np.prod(np.shape(x))) if np.shape(x) else 1)
        for x in jax.tree.leaves(tree)
    )
    if n == 0:
        return 1.0
    return compressed_bytes(tree, config) / (4.0 * n)


# ---------------------------------------------------------- error feedback
class ErrorFeedback:
    """EF residual state: ``compress(update + residual)``, carry the error."""

    @staticmethod
    def init(tree):
        return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), tree)

    @staticmethod
    def compress(tree, residual, compressor) -> Tuple[object, object]:
        """Returns (compressed-then-decompressed update, new residual)."""
        corrected = jax.tree.map(
            lambda x, r: x.astype(jnp.float32) + r, tree, residual
        )
        sent = compressor(corrected)
        new_residual = jax.tree.map(lambda c, s: c - s.astype(jnp.float32), corrected, sent)
        return sent, new_residual


def int8_roundtrip(tree):
    """Convenience compressor for ErrorFeedback: int8 quantize-dequantize."""
    v, s = quantize_int8(tree)
    return dequantize_int8(v, s)
