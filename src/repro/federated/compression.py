"""Update compression for the device→server uplink (related-work axis:
gradient sparsification / quantization in FL [paper §7]).

DropPEFT already shrinks uploads structurally (PEFT modules × PTLS layer
masks); these are the orthogonal bit-level compressors stacked on top:

* ``quantize_int8`` / ``dequantize_int8`` — per-leaf symmetric int8 with a
  fp32 scale (4.06x over fp32 at <0.4% RMS error on LoRA-scale updates).
* ``topk_sparsify`` — magnitude top-k with index+value encoding.
* ``ErrorFeedback`` — residual accumulation so repeated lossy uploads stay
  unbiased over rounds (Seide et al. / EF-SGD semantics).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(tree):
    """pytree -> (int8 tree, fp32 scale tree).  Symmetric per-leaf."""

    def q(x):
        xf = x.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
        return jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8), scale

    pairs = jax.tree.map(q, tree)
    vals = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    scales = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return vals, scales


def dequantize_int8(vals, scales, dtype=jnp.float32):
    return jax.tree.map(lambda v, s: (v.astype(jnp.float32) * s).astype(dtype), vals, scales)


def topk_sparsify(tree, fraction: float):
    """Keep the top-``fraction`` entries by magnitude per leaf (zeros else)."""

    def sp(x):
        xf = x.astype(jnp.float32)
        flat = jnp.abs(xf).reshape(-1)
        k = max(1, int(fraction * flat.shape[0]))
        thresh = jnp.sort(flat)[-k]
        return jnp.where(jnp.abs(xf) >= thresh, xf, 0.0).astype(x.dtype)

    return jax.tree.map(sp, tree)


def compressed_bytes(tree, *, int8: bool = True, sparsity: float = 1.0) -> int:
    """Uplink bytes after compression (for the SystemModel traffic column)."""
    n = sum(int(x.size) for x in jax.tree.leaves(tree))
    n_leaves = len(jax.tree.leaves(tree))
    per_entry = 1 if int8 else 4
    payload = int(n * sparsity) * per_entry
    if sparsity < 1.0:
        payload += int(n * sparsity) * 4  # indices
    return payload + n_leaves * 4  # scales


class ErrorFeedback:
    """EF residual state: ``compress(update + residual)``, carry the error."""

    @staticmethod
    def init(tree):
        return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), tree)

    @staticmethod
    def compress(tree, residual, compressor) -> Tuple[object, object]:
        """Returns (compressed-then-decompressed update, new residual)."""
        corrected = jax.tree.map(
            lambda x, r: x.astype(jnp.float32) + r, tree, residual
        )
        sent = compressor(corrected)
        new_residual = jax.tree.map(lambda c, s: c - s.astype(jnp.float32), corrected, sent)
        return sent, new_residual


def int8_roundtrip(tree):
    """Convenience compressor for ErrorFeedback: int8 quantize-dequantize."""
    v, s = quantize_int8(tree)
    return dequantize_int8(v, s)
