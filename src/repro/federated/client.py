"""Client-side local fine-tuning with STLD (paper §3.1-3.2).

``make_client_fns`` builds the jit'd per-round programs and returns them as a
:class:`ClientFns` namedtuple:

* ``local_round``  — ``lax.scan`` over local mini-batch steps; each step
  draws fresh STLD gates (Bernoulli per layer, or gather-mode indices),
  computes PEFT-only grads, AdamW-updates the PEFT tree, and accumulates
  the Eq.-6 PTLS importance statistics.
* ``evaluate``     — full-model (no dropout) classification accuracy on the
  device's local validation split.
* ``cohort_round`` — the batched cohort engine: ``jax.vmap`` of the local
  round over a leading device axis.  One jit'd call trains a whole cohort
  from stacked per-device batches, a per-device ``mean_rate`` vector, split
  PRNG keys, and per-device global-step offsets.  Each device starts from a
  fresh AdamW state (exactly what the simulator does per round), so the
  optimizer state never crosses the device axis.
* ``cohort_evaluate`` — vmapped validation over the device axis.  Val shards
  have heterogeneous sizes, so batches arrive padded to a common size with a
  ``valid`` row mask; the masked mean equals the per-device plain mean.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import peft as peft_lib
from repro.core import ptls, stld
from repro.core.schedules import unit_shape
from repro.models.losses import softmax_xent
from repro.models.registry import model_apply
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, make_lr_schedule


class ClientFns(NamedTuple):
    local_round: Callable
    evaluate: Callable
    cohort_round: Callable
    cohort_evaluate: Callable
    cohort_round_eval: Callable


def _model_batch(cfg, tokens):
    batch = {"tokens": tokens}
    if cfg.modality == "vision":
        b = tokens.shape[0]
        batch["patches"] = jnp.zeros((b, cfg.frontend_seq, cfg.d_model), dtype=cfg.dtype)
    if cfg.modality == "audio":
        b = tokens.shape[0]
        batch["frames"] = jnp.zeros((b, cfg.frontend_seq, cfg.d_model), dtype=cfg.dtype)
    return batch


def _logits_for_tokens(cfg, logits, tokens):
    """Strip any stub-frontend prefix so logits align with token positions."""
    if cfg.modality == "vision":
        return logits[:, -tokens.shape[1] :]
    return logits


def make_client_fns(
    cfg,
    peft_cfg,
    stld_cfg,
    train_cfg,
    *,
    stack_mode: str = "unroll",
    donate: Optional[bool] = None,
) -> ClientFns:
    """Build the jit'd per-round client programs.

    PEFT/base trees arrive in either layer layout; the stacked-native layout
    shrinks the dispatch pytree from O(L·k) to O(k) leaves and removes every
    traced ``jnp.stack`` of base-layer params from the compiled programs.

    ``donate`` (default: auto — on for non-CPU backends, where XLA actually
    implements buffer donation) donates the round-scoped buffers to their
    jit'd programs so each round's PEFT/optimizer update can reuse the input
    allocation instead of holding both copies live: ``local_round`` donates
    its fresh AdamW state, ``cohort_round_eval`` its stacked cohort PEFT
    input.  ``cohort_round`` never donates — its FedAdaOPT caller truncates
    against the start stack after the call returns.
    """
    if donate is None:
        donate = jax.default_backend() != "cpu"
    lora_sc = peft_lib.lora_scale(peft_cfg) if peft_cfg.method == "lora" else 1.0
    sched = make_lr_schedule(
        train_cfg.schedule, train_cfg.learning_rate, train_cfg.warmup_steps, train_cfg.total_steps
    )
    gather_mode = stld_cfg.mode == "gather"

    def loss_fn(peft_params, base_params, tokens, targets, mask, drops, active_idx):
        logits, aux, _ = model_apply(
            base_params,
            cfg,
            _model_batch(cfg, tokens),
            drops=drops,
            peft=peft_params,
            lora_scale=lora_sc,
            stack_mode="gather" if active_idx is not None else stack_mode,
            active_idx=active_idx,
        )
        logits = _logits_for_tokens(cfg, logits, tokens)
        loss, metrics = softmax_xent(logits, targets, mask)
        loss = loss + cfg.router_aux_coef * aux
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _local_round(
        base_params,
        peft_params,
        opt_state,
        batches,  # dict of arrays with leading (steps,) dim
        mean_rate,  # scalar: this round's dropout-rate config (the bandit arm)
        rng,
        global_step,
        num_active: Optional[int] = None,
    ):
        shape = unit_shape(stld_cfg.distribution, cfg.num_layers)
        rates = jnp.clip(shape * mean_rate, 0.0, 0.95)
        if not stld_cfg.enabled:
            rates = jnp.zeros((cfg.num_layers,))
        imp0 = ptls.ImportanceAccumulator.init(cfg.num_layers)

        def step(carry, xs):
            peft_p, opt, imp, rng, gstep = carry
            tokens, targets, mask = xs
            rng, kd = jax.random.split(rng)
            if gather_mode and num_active is not None:
                active_idx = stld.sample_active_indices(kd, rates, num_active)
                drops = None
                drops_for_imp = jnp.ones((cfg.num_layers,)).at[active_idx].set(0.0)
            else:
                drops = stld.sample_drops(kd, rates, stld_cfg.min_active_layers)
                active_idx = None
                drops_for_imp = drops.astype(jnp.float32)
            (loss, metrics), grads = grad_fn(
                peft_p, base_params, tokens, targets, mask, drops, active_idx
            )
            gnorms = ptls.layer_grad_norms(grads, cfg.num_layers)
            imp = ptls.ImportanceAccumulator.update(imp, gnorms, drops_for_imp)
            grads, gn = clip_by_global_norm(grads, train_cfg.grad_clip)
            peft_p, opt = adamw_update(
                grads,
                opt,
                peft_p,
                lr=sched(gstep),
                beta1=train_cfg.beta1,
                beta2=train_cfg.beta2,
                eps=train_cfg.eps,
                weight_decay=train_cfg.weight_decay,
            )
            out_metrics = {
                "loss": metrics["loss"],
                "accuracy": metrics["accuracy"],
                "grad_norm": gn,
                "active_layers": jnp.sum(1.0 - drops_for_imp),
            }
            return (peft_p, opt, imp, rng, gstep + 1), out_metrics

        xs = (batches["tokens"], batches["targets"], batches["mask"])
        (peft_params, opt_state, imp, _, _), metrics = jax.lax.scan(
            step, (peft_params, opt_state, imp0, rng, global_step), xs
        )
        metrics = jax.tree.map(jnp.mean, metrics)
        importance = ptls.ImportanceAccumulator.importance(imp)
        return peft_params, opt_state, metrics, importance

    local_round = jax.jit(
        _local_round,
        static_argnames=("num_active",),
        donate_argnums=(2,) if donate else (),  # the per-round AdamW state
    )

    @partial(jax.jit, static_argnames=("num_active",))
    def cohort_round(
        base_params,
        peft_stack,     # PEFT pytree with leading (N,) device axis on every leaf
        batch_stack,    # dict of (N, steps, ...) arrays
        rates,          # (N,) per-device mean dropout rates
        rngs,           # (N, 2) split PRNG keys, one per device
        global_steps,   # (N,) per-device LR-schedule offsets
        num_active: Optional[int] = None,
    ):
        """Train the whole cohort in one call: vmap of ``local_round``.

        ``num_active`` is static (gather mode); a cohort with heterogeneous
        static counts must be partitioned into same-count groups by the
        caller (the simulator does this).  Returns stacked
        ``(peft_stack, metrics, importances)``.
        """

        def one(peft_params, batches, rate, rng, gstep):
            opt0 = adamw_init(peft_params)
            peft_p, _, metrics, importance = _local_round(
                base_params, peft_params, opt0, batches, rate, rng, gstep, num_active
            )
            return peft_p, metrics, importance

        return jax.vmap(one)(peft_stack, batch_stack, rates, rngs, global_steps)

    def _class_logits(base_params, peft_params, tokens, num_classes_arr):
        """Label-token logits at the final position (synthetic task protocol)."""
        logits, _, _ = model_apply(
            base_params,
            cfg,
            _model_batch(cfg, tokens),
            peft=peft_params,
            lora_scale=lora_sc,
            stack_mode=stack_mode,
        )
        logits = _logits_for_tokens(cfg, logits, tokens)
        final = logits[:, -1].astype(jnp.float32)  # (B, V)
        return final[:, 1 : 1 + num_classes_arr.shape[0]]

    @jax.jit
    def evaluate(base_params, peft_params, tokens, labels, num_classes_arr):
        """Classification accuracy: argmax over label-token logits at the
        final position (synthetic task protocol)."""
        class_logits = _class_logits(base_params, peft_params, tokens, num_classes_arr)
        pred = jnp.argmax(class_logits, axis=-1)
        return jnp.mean((pred == labels).astype(jnp.float32))

    def _masked_accuracy(base_params, peft_params, toks, labs, v, num_classes_arr):
        class_logits = _class_logits(base_params, peft_params, toks, num_classes_arr)
        pred = jnp.argmax(class_logits, axis=-1)
        correct = (pred == labs).astype(jnp.float32) * v
        return jnp.sum(correct) / jnp.maximum(jnp.sum(v), 1.0)

    @jax.jit
    def cohort_evaluate(base_params, peft_stack, tokens, labels, valid, num_classes_arr):
        """Per-device accuracies (N,) from padded (N, B, S) val batches;
        ``valid`` is the (N, B) row mask for the padding."""

        def one(peft_params, toks, labs, v):
            return _masked_accuracy(base_params, peft_params, toks, labs, v, num_classes_arr)

        return jax.vmap(one)(peft_stack, tokens, labels, valid)

    @partial(
        jax.jit,
        static_argnames=("num_active",),
        # the stacked cohort PEFT input is rebuilt fresh every round; donate
        # it so the round's output can alias the input allocation
        donate_argnums=(1,) if donate else (),
    )
    def cohort_round_eval(
        base_params,
        peft_stack,
        batch_stack,
        rates,
        rngs,
        global_steps,
        val_tokens,
        val_labels,
        val_valid,
        num_classes_arr,
        num_active: Optional[int] = None,
    ):
        """Fused cohort train + validation: one dispatch per round so the
        per-call overhead (arg flattening of the ~100-leaf base tree, program
        launch) is paid once for the whole cohort instead of 2N times."""

        def one(peft_params, batches, rate, rng, gstep, toks, labs, v):
            opt0 = adamw_init(peft_params)
            peft_p, _, metrics, importance = _local_round(
                base_params, peft_params, opt0, batches, rate, rng, gstep, num_active
            )
            acc = _masked_accuracy(base_params, peft_p, toks, labs, v, num_classes_arr)
            return peft_p, metrics, importance, acc

        return jax.vmap(one)(
            peft_stack, batch_stack, rates, rngs, global_steps,
            val_tokens, val_labels, val_valid,
        )

    return ClientFns(local_round, evaluate, cohort_round, cohort_evaluate, cohort_round_eval)
