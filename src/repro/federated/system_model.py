"""Analytic device/communication system model (paper §6.1, Table 1-2).

Reproduces the paper's wall-clock / memory / energy / traffic accounting
deterministically: on-device times in the paper were *measured* on Jetson
boards; here they are derived from per-round FLOPs/bytes and published
device capabilities (Table 2), which is the standard semi-emulation setup
the paper itself uses for the federation layer.

All quantities honour STLD: a round with expected active-layer fraction
``rho = E[L-tilde]/L`` scales layer compute, layer activations, and
layer-local PEFT state by ``rho`` (paper §3.2 overhead analysis); PTLS
scales upload traffic by the shared-layer fraction (paper §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    flops: float          # effective trainable FLOP/s (bf16, incl. utilisation)
    memory_gb: float
    compute_watts: float
    radio_watts: float


# Jetson boards from paper Table 2.  "flops" folds a ~30% training
# utilisation factor into the headline TOPS number.
DEVICE_PROFILES = {
    "tx2": DeviceProfile("tx2", 0.6e12, 8.0, 15.0, 2.0),
    "nx": DeviceProfile("nx", 6.3e12, 16.0, 20.0, 2.0),
    "agx": DeviceProfile("agx", 9.6e12, 32.0, 30.0, 2.0),
}


@dataclass
class RoundCost:
    compute_time_s: float
    comm_time_s: float
    memory_gb: float
    energy_j: float
    traffic_mb: float

    @property
    def total_time_s(self) -> float:
        return self.compute_time_s + self.comm_time_s


@dataclass
class CohortCost:
    """Vectorized :class:`RoundCost` over a cohort: every field is (N,)."""

    compute_time_s: np.ndarray
    comm_time_s: np.ndarray
    memory_gb: np.ndarray
    energy_j: np.ndarray
    traffic_mb: np.ndarray

    @property
    def total_time_s(self) -> np.ndarray:
        return self.compute_time_s + self.comm_time_s


@dataclass
class MemoryBreakdown:
    params_gb: float
    activations_gb: float
    gradients_gb: float
    optimizer_gb: float

    @property
    def total_gb(self) -> float:
        return self.params_gb + self.activations_gb + self.gradients_gb + self.optimizer_gb


class SystemModel:
    """Per-round cost model for one (model config, PEFT config) pair."""

    def __init__(self, cfg, peft_cfg=None, *, peft_params: int = 0, dtype_bytes: int = 2):
        self.cfg = cfg
        self.peft_cfg = peft_cfg
        self.dtype_bytes = dtype_bytes
        counts = cfg.param_counts()
        self.total_params = counts["total"]
        self.active_params = counts["active"]
        self.peft_params = peft_params or self._default_peft_params()

    def _default_peft_params(self) -> int:
        if self.peft_cfg is None:
            return 0
        cfg, p = self.cfg, self.peft_cfg
        d, hd = cfg.d_model, cfg.resolved_head_dim
        if p.method == "lora":
            per_layer = 0
            for t in p.lora_targets:
                if t == "q":
                    per_layer += p.lora_rank * (d + cfg.num_heads * hd)
                elif t in ("k", "v"):
                    per_layer += p.lora_rank * (d + cfg.num_kv_heads * hd)
                elif t == "o":
                    per_layer += p.lora_rank * (cfg.num_heads * hd + d)
                elif t in ("up", "gate"):
                    per_layer += p.lora_rank * (d + cfg.d_ff)
                elif t == "down":
                    per_layer += p.lora_rank * (cfg.d_ff + d)
            return per_layer * cfg.num_layers
        if p.method == "adapter":
            return 2 * (2 * cfg.d_model * p.adapter_dim) * cfg.num_layers
        if p.method == "bitfit":
            return 2 * cfg.d_model * cfg.num_layers
        return 0

    # ------------------------------------------------------------- pieces
    def flops_per_token(self, *, training: bool, peft: bool, active_fraction: float = 1.0) -> float:
        """Forward (+backward) FLOPs per token.

        forward = 2 * N_active; full backward = 4 * N (2 for dL/dx, 2 for
        dL/dW); PEFT backward skips frozen weight grads -> ~2 * N + small.
        STLD scales the layer component by ``active_fraction`` (embeddings
        and head are never dropped).
        """
        emb = self.cfg.param_counts()["embedding"]
        # embedding lookup is a gather (no FLOPs); the LM head is one
        # emb-sized matmul and is never dropped by STLD.
        layer_params = max(self.active_params - emb, 0)
        fwd = 2 * (layer_params * active_fraction + emb)
        if not training:
            return fwd
        if peft:
            bwd = fwd + 6 * self.peft_params * active_fraction
        else:
            bwd = 2 * fwd
        return fwd + bwd

    def activation_bytes_per_token(self, active_fraction: float = 1.0) -> float:
        """Stored-activation bytes per token for the backward pass.

        Calibrated to HF-Transformers-style training (the paper's stack),
        which retains every sublayer intermediate: norms, qkv/o (and their
        pre-GELU states), attention probs, both MLP halves, residuals —
        about 20*d + 4*ff per token per layer in compute dtype (matches the
        paper's Fig. 3 proportions at DeBERTa scale within ~15%).
        """
        cfg = self.cfg
        per_layer = (20 * cfg.d_model + 4 * cfg.d_ff) * self.dtype_bytes
        if cfg.num_experts > 0:
            per_layer += 2 * cfg.num_experts * self.dtype_bytes  # router probs
        return per_layer * cfg.num_layers * active_fraction + 2 * cfg.d_model * self.dtype_bytes

    def memory_breakdown(
        self,
        *,
        batch: int,
        seq: int,
        peft: bool,
        full_ft: bool = False,
        active_fraction: float = 1.0,
    ) -> MemoryBreakdown:
        gb = 1024.0**3
        params = self.total_params * self.dtype_bytes / gb
        act = self.activation_bytes_per_token(active_fraction) * batch * seq / gb
        if full_ft:
            grads = self.total_params * self.dtype_bytes / gb
            opt = self.total_params * 2 * self.dtype_bytes / gb  # bf16 m+v (paper Fig. 3)
        elif peft:
            grads = self.peft_params * active_fraction * 4 / gb
            opt = self.peft_params * active_fraction * 8 / gb
        else:
            grads = opt = 0.0
        return MemoryBreakdown(params, act, grads, opt)

    def comm_bytes(
        self, *, peft: bool, share_fraction: float = 1.0, uplink_ratio=1.0
    ) -> float:
        """Per-round up+down traffic (fp32 updates, paper §2.2).

        ``uplink_ratio`` is the compressed/fp32 size factor of the uplink
        payload (``repro.federated.compression.uplink_ratio``); it scales
        the *up* component only — the server→device broadcast stays fp32.
        The default 1.0 is exact (no compression billed)."""
        n = self.peft_params if peft else self.total_params
        up = n * share_fraction * 4 * uplink_ratio
        down = n * 4
        return up + down

    # -------------------------------------------------------------- rounds
    def round_cost(
        self,
        *,
        device: str = "nx",
        bandwidth_mbps: float = 40.0,
        batch: int = 16,
        seq: int = 128,
        local_steps: int = 4,
        peft: bool = True,
        full_ft: bool = False,
        active_fraction: float = 1.0,
        share_fraction: float = 1.0,
    ) -> RoundCost:
        cohort = self.cohort_round_cost(
            devices=[device],
            bandwidth_mbps=bandwidth_mbps,
            batch=batch,
            seq=seq,
            local_steps=local_steps,
            peft=peft,
            full_ft=full_ft,
            active_fraction=active_fraction,
            share_fraction=share_fraction,
        )
        return RoundCost(
            compute_time_s=float(cohort.compute_time_s[0]),
            comm_time_s=float(cohort.comm_time_s[0]),
            memory_gb=float(cohort.memory_gb[0]),
            energy_j=float(cohort.energy_j[0]),
            traffic_mb=float(cohort.traffic_mb[0]),
        )

    def cohort_round_cost(
        self,
        *,
        devices: Sequence[str],
        bandwidth_mbps,
        batch: int = 16,
        seq: int = 128,
        local_steps: int = 4,
        peft: bool = True,
        full_ft: bool = False,
        active_fraction=1.0,
        share_fraction=1.0,
        uplink_ratio=1.0,
    ) -> CohortCost:
        """Vectorized :meth:`round_cost` over a whole cohort.

        ``devices`` is a length-N list of profile names; ``bandwidth_mbps``,
        ``active_fraction``, ``share_fraction`` and ``uplink_ratio``
        broadcast as (N,) arrays.  The per-token helpers are affine in
        those fractions, so they accept arrays directly and the whole
        cohort's accounting is a handful of numpy ops instead of N python
        calls.
        """
        n = len(devices)
        af = np.broadcast_to(np.asarray(active_fraction, dtype=np.float64), (n,))
        sf = np.broadcast_to(np.asarray(share_fraction, dtype=np.float64), (n,))
        ur = np.broadcast_to(np.asarray(uplink_ratio, dtype=np.float64), (n,))
        bw = np.broadcast_to(np.asarray(bandwidth_mbps, dtype=np.float64), (n,))
        profs = [DEVICE_PROFILES[d] for d in devices]
        cap = np.array([p.flops for p in profs])
        compute_watts = np.array([p.compute_watts for p in profs])
        radio_watts = np.array([p.radio_watts for p in profs])

        tokens = batch * seq * local_steps
        peft_train = peft and not full_ft
        flops = tokens * self.flops_per_token(
            training=True, peft=peft_train, active_fraction=af
        )
        compute_time = flops / cap
        bytes_ = self.comm_bytes(
            peft=peft_train, share_fraction=sf, uplink_ratio=ur
        )
        comm_time = bytes_ * 8 / (bw * 1e6)
        mem = self.memory_breakdown(
            batch=batch, seq=seq, peft=peft_train, full_ft=full_ft, active_fraction=af
        )
        energy = compute_watts * compute_time + radio_watts * comm_time
        return CohortCost(
            compute_time_s=compute_time,
            comm_time_s=comm_time,
            memory_gb=np.broadcast_to(np.asarray(mem.total_gb, dtype=np.float64), (n,)),
            energy_j=energy,
            traffic_mb=np.broadcast_to(np.asarray(bytes_ / 1024.0**2, dtype=np.float64), (n,)),
        )


def sample_bandwidth(rng: np.random.Generator, low: float = 1.0, high: float = 100.0) -> float:
    """Per-device bandwidth fluctuating in [1, 100] Mbps (paper §6.1)."""
    return float(rng.uniform(low, high))


def sample_device(rng: np.random.Generator) -> str:
    return str(rng.choice(list(DEVICE_PROFILES)))
