from repro.federated.system_model import DEVICE_PROFILES, RoundCost, SystemModel
from repro.federated.simulator import FederatedSimulator, SimResult

__all__ = [
    "DEVICE_PROFILES",
    "RoundCost",
    "SystemModel",
    "FederatedSimulator",
    "SimResult",
]
