from repro.federated.algorithms import (
    FederatedAlgorithm,
    get_algorithm,
    register,
    registered_methods,
)
from repro.federated.engine import CohortEngine
from repro.federated.runner import ExperimentRunner, SimResult, run_replicates
from repro.federated.scheduler import (
    ScheduleConfig,
    VirtualClockScheduler,
    feasible_rate_floor,
    resolve_schedule,
)
from repro.federated.simulator import METHODS, FederatedSimulator, Strategy
from repro.federated.state import CohortResults, RoundPlan, RoundState
from repro.federated.system_model import DEVICE_PROFILES, RoundCost, SystemModel

__all__ = [
    "DEVICE_PROFILES",
    "RoundCost",
    "SystemModel",
    "FederatedAlgorithm",
    "register",
    "get_algorithm",
    "registered_methods",
    "CohortEngine",
    "ExperimentRunner",
    "ScheduleConfig",
    "VirtualClockScheduler",
    "feasible_rate_floor",
    "resolve_schedule",
    "run_replicates",
    "SimResult",
    "RoundState",
    "RoundPlan",
    "CohortResults",
    "FederatedSimulator",
    "Strategy",
    "METHODS",
]
