"""Deterministic fault injection for the virtual-clock scheduler.

A production federation of intermittently-connected edge devices fails in
specific, recurring ways: clients vanish mid-round, uplinks collapse to a
trickle, corrupted updates arrive as NaNs, devices churn in and out of the
population, and the server itself restarts.  This module makes every one of
those failure modes a *reproducible* event on the scheduler's virtual
clock:

* :class:`FaultPlan` — a frozen, JSON-serializable description of which
  faults fire with what probability/schedule, plus the retry policy.
* :class:`FaultInjector` — the plan's executor.  Every random draw is keyed
  by ``(plan.seed, fault kind, dispatch round, device)`` through its own
  ``numpy`` bit generator, so outcomes are a pure function of the plan and
  the dispatch coordinates — independent of draw order, cohort execution
  mode (batched vs sequential), and everything else the scheduler does.
  Identical plans therefore produce identical fault sequences and identical
  event logs, which the determinism suite asserts.
* :class:`ServerKilled` — raised by the scheduler after the checkpoint at a
  planned kill round; the caller rebuilds the runner with ``resume=True``
  and continues bit-exactly (the crash-restart drill for the durable
  checkpoint layer).

Fault semantics (threaded through
:class:`~repro.federated.scheduler.VirtualClockScheduler`):

* **client dropout** — the device completes a random fraction of its local
  round and vanishes.  Its update never aggregates; the burned compute,
  energy, and partial traffic are still billed; the device re-enters the
  dispatch pool only after an exponential virtual-time backoff.
* **bandwidth collapse** — the device's uplink slows by
  ``bandwidth_collapse_factor``; the update arrives late (possibly past a
  deadline) but intact.
* **NaN update** — the update arrives on time but its PEFT tree is
  non-finite; aggregation screens it out (and the traced aggregators
  carry a last-line ``is_finite`` guard even if screening were bypassed).
* **device churn** — a device is unavailable for dispatch inside
  ``[t_leave, t_rejoin)`` virtual-time windows.
* **server kill** — :class:`ServerKilled` after the checkpoint at the
  planned round.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Optional, Tuple

import numpy as np

__all__ = ["FaultPlan", "FaultInjector", "ServerKilled"]


class ServerKilled(RuntimeError):
    """Simulated server crash (``FaultPlan.kill_at_rounds``).

    Raised *after* the round's checkpoint is durably on disk, so the drill
    is exactly a production restart: rebuild the runner with
    ``resume=True`` and the run continues bit-identically to one that was
    never killed.
    """


@dataclass(frozen=True)
class FaultPlan:
    """Seeded, declarative description of every fault a run will see.

    All probabilities are per dispatched job.  ``nan_updates`` pins
    corruptions to exact ``(dispatch_round, device)`` coordinates on top of
    the probabilistic ``nan_update_prob``.  ``churn`` rows are
    ``(device, t_leave, t_rejoin)`` virtual-time unavailability windows.
    A default-constructed plan (``FaultPlan()``) injects nothing and is
    bit-transparent: attaching it must not change any result array.
    """

    seed: int = 0
    dropout_prob: float = 0.0
    dropout_frac: Tuple[float, float] = (0.3, 0.9)   # completed fraction range
    bandwidth_collapse_prob: float = 0.0
    bandwidth_collapse_factor: float = 8.0           # comm-time multiplier
    nan_update_prob: float = 0.0
    nan_updates: Tuple[Tuple[int, int], ...] = ()    # (dispatch_round, device)
    churn: Tuple[Tuple[int, float, float], ...] = () # (device, t_leave, t_rejoin)
    kill_at_rounds: Tuple[int, ...] = ()             # ServerKilled after ckpt
    retry_backoff_s: float = 30.0                    # first-retry virtual delay
    max_backoff_s: float = 600.0                     # exponential backoff cap

    def __post_init__(self):
        for name in ("dropout_prob", "bandwidth_collapse_prob", "nan_update_prob"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        lo, hi = self.dropout_frac
        if not (0.0 < lo <= hi <= 1.0):
            raise ValueError(
                f"dropout_frac must satisfy 0 < lo <= hi <= 1, got {self.dropout_frac}"
            )
        if self.bandwidth_collapse_factor < 1.0:
            raise ValueError(
                f"bandwidth_collapse_factor must be >= 1, "
                f"got {self.bandwidth_collapse_factor}"
            )
        if self.retry_backoff_s <= 0 or self.max_backoff_s < self.retry_backoff_s:
            raise ValueError(
                "need 0 < retry_backoff_s <= max_backoff_s, got "
                f"{self.retry_backoff_s}/{self.max_backoff_s}"
            )
        # normalize JSON-loaded lists into hashable tuples
        object.__setattr__(self, "dropout_frac", tuple(self.dropout_frac))
        object.__setattr__(
            self, "nan_updates", tuple(tuple(x) for x in self.nan_updates)
        )
        object.__setattr__(self, "churn", tuple(tuple(x) for x in self.churn))
        object.__setattr__(self, "kill_at_rounds", tuple(self.kill_at_rounds))

    @property
    def any_faults(self) -> bool:
        return bool(
            self.dropout_prob
            or self.bandwidth_collapse_prob
            or self.nan_update_prob
            or self.nan_updates
            or self.churn
            or self.kill_at_rounds
        )

    # ------------------------------------------------------------- (de)serde
    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls(**json.loads(text))

    @classmethod
    def from_file(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())


def resolve_fault_plan(plan) -> Optional[FaultPlan]:
    """Normalize None | FaultPlan | dict | JSON-file path into a plan."""
    if plan is None or isinstance(plan, FaultPlan):
        return plan
    if isinstance(plan, dict):
        return FaultPlan(**plan)
    if isinstance(plan, str):
        return FaultPlan.from_file(plan)
    raise TypeError(
        f"fault_plan must be a FaultPlan, dict, or JSON path, got {plan!r}"
    )


# Distinct substream per fault kind so e.g. enabling bandwidth collapse
# cannot shift which devices drop out under the same seed.
_KIND = {"dropout": 1, "dropout_frac": 2, "bandwidth": 3, "nan": 4}


@dataclass
class FaultInjector:
    """Executes a :class:`FaultPlan` with order-independent randomness."""

    plan: FaultPlan
    _nan_set: frozenset = field(init=False)

    def __post_init__(self):
        self._nan_set = frozenset(self.plan.nan_updates)

    def _u(self, kind: str, round_index: int, dev: int) -> float:
        """One uniform draw, a pure function of (seed, kind, round, dev)."""
        rng = np.random.default_rng(
            (self.plan.seed, _KIND[kind], round_index, dev)
        )
        return float(rng.random())

    # -------------------------------------------------------- per-fault API
    def dropout_at(self, round_index: int, dev: int) -> Optional[float]:
        """Completed-fraction of the job if the client drops, else None."""
        p = self.plan.dropout_prob
        if p <= 0.0 or self._u("dropout", round_index, dev) >= p:
            return None
        lo, hi = self.plan.dropout_frac
        return lo + (hi - lo) * self._u("dropout_frac", round_index, dev)

    def bandwidth_factor_at(self, round_index: int, dev: int) -> float:
        p = self.plan.bandwidth_collapse_prob
        if p > 0.0 and self._u("bandwidth", round_index, dev) < p:
            return self.plan.bandwidth_collapse_factor
        return 1.0

    def corrupts(self, round_index: int, dev: int) -> bool:
        if (round_index, dev) in self._nan_set:
            return True
        p = self.plan.nan_update_prob
        return p > 0.0 and self._u("nan", round_index, dev) < p

    def unavailable(self, dev: int, t: float) -> bool:
        """Is ``dev`` churned out of the population at virtual time ``t``?"""
        return any(
            d == dev and t_leave <= t < t_rejoin
            for d, t_leave, t_rejoin in self.plan.churn
        )

    def next_rejoin(self, dev: int, t: float) -> Optional[float]:
        """Earliest rejoin instant > ``t`` for a currently-churned device."""
        times = [
            t_rejoin
            for d, t_leave, t_rejoin in self.plan.churn
            if d == dev and t_leave <= t < t_rejoin
        ]
        return min(times) if times else None

    def kills_after(self, round_index: int) -> bool:
        return round_index in self.plan.kill_at_rounds

    def backoff_s(self, consecutive_failures: int) -> float:
        """Exponential virtual-time backoff for the n-th consecutive
        failure of one device (n >= 1), capped at ``max_backoff_s``."""
        return min(
            self.plan.retry_backoff_s * (2.0 ** (consecutive_failures - 1)),
            self.plan.max_backoff_s,
        )
