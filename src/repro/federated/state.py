"""Round-loop state containers for the composable federated-algorithm API.

:class:`RoundState` is the single immutable value threaded through every
lifecycle hook of a :class:`~repro.federated.algorithms.FederatedAlgorithm`.
It is registered as a JAX pytree: the array-valued fields (PRNG key, global
PEFT tree, per-device PEFT trees, PTLS share masks, per-device error-feedback
residuals) are pytree data, while
host-side bookkeeping (round counters, the numpy cohort-sampling generator,
the bandit configurator, the metric history) rides along as metadata.  Hooks
never mutate a state in place — they return a new one via
:func:`dataclasses.replace` — so the runner can checkpoint any round
boundary and resume bit-exactly.

:class:`RoundPlan` is what ``configure_round`` produces (cohort, dropout
rates, progressive depth); :class:`CohortResults` carries the per-device
outputs of ``cohort_step`` plus whatever later hooks attach (share masks,
system-model costs).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax


@dataclass(frozen=True)
class RoundState:
    """Immutable snapshot of a federated experiment between rounds."""

    key: Any                                  # jax PRNG key
    global_peft: Any                          # server-side PEFT pytree
    device_peft: Dict[int, Any] = field(default_factory=dict)
    last_mask: Dict[int, Any] = field(default_factory=dict)   # PTLS share masks
    ef_residual: Dict[int, Any] = field(default_factory=dict)  # EF residual trees
    round_index: int = 0
    global_step: int = 0                      # LR-schedule offset
    cum_time: float = 0.0                     # simulated wall-clock (s)
    virtual_time: float = 0.0                 # scheduler clock (== cum_time in sync)
    server_version: int = 0                   # aggregations applied (staleness base)
    prev_acc: Dict[int, float] = field(default_factory=dict)
    rng: Any = None                           # numpy Generator (cohorts, bandwidth)
    configurator: Any = None                  # OnlineConfigurator | None
    history: Tuple[dict, ...] = ()            # one metrics row per finished round


jax.tree_util.register_dataclass(
    RoundState,
    data_fields=("key", "global_peft", "device_peft", "last_mask", "ef_residual"),
    meta_fields=(
        "round_index",
        "global_step",
        "cum_time",
        "virtual_time",
        "server_version",
        "prev_acc",
        "rng",
        "configurator",
        "history",
    ),
)


@dataclass
class RoundPlan:
    """What ``configure_round`` decided for one round."""

    round_index: int
    cohort: List[int]
    rates: List[float]                 # per-device mean dropout rates
    adaopt_depth: int                  # progressive depth (== num_layers when off)
    start_pefts: Optional[list] = None # filled by the runner via client_init
    compression: Optional[List[str]] = None  # per-device uplink levels | None


@dataclass
class CohortResults:
    """Per-device outputs of one trained cohort, in cohort order."""

    plan: RoundPlan
    pefts: list                        # updated PEFT trees
    metrics: list                      # per-device dicts (loss/accuracy/...)
    importances: list                  # PTLS layer importances
    accuracies: List[float]            # local-val accuracy after the round
    masks: Any = None                  # (N, L) bool share masks (aggregate)
    cost: Any = None                   # SystemModel RoundCost (report)
    staleness: Any = None              # (N,) int server-version lag (async/carry)
    weights: Any = None                # (N,) staleness aggregation weights | None
    uplink_pefts: Optional[list] = None  # server-side reconstructions (merge)
    uplink_ratio: Any = None           # (N,) compressed/fp32 uplink factor | None
