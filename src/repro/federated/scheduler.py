"""Event-driven virtual-clock scheduler: straggler-aware round execution.

The paper's headline claim is a *wall-clock* one — 1.3–6.3x faster
convergence on heterogeneous Jetson cohorts — yet a barrier-synchronous
round loop lets the :class:`~repro.federated.system_model.SystemModel`'s
per-device times influence only what gets *reported*, never what gets
*trained*.  This module replaces the implicit lock-step loop with a
priority queue of device-completion events driven by
``SystemModel.cohort_round_cost``, behind one :class:`ScheduleConfig`:

* ``sync`` — today's semantics: the round closes when the slowest cohort
  member finishes.  This path calls the algorithm lifecycle hooks in
  exactly the pre-scheduler order and consumes identical RNG streams, so
  its ``SimResult`` is bit-for-bit the PR-2 runner's
  (``tests/test_schedule_parity.py``).
* ``deadline`` — the round closes at ``virtual_time + deadline_s`` (or when
  everyone finishes, whichever is earlier; never before the first
  arrival).  Stragglers are ``"drop"``-ped (their updates are discarded,
  their burned compute still billed) or ``"carry"``-ed (their updates stay
  in flight and aggregate in a later round — with a staleness discount
  when ``staleness_alpha > 0``; the default ``0`` aggregates stale and
  fresh updates at equal weight).  ``deadline_s=inf`` + ``staleness_alpha=0``
  is exactly ``sync``.
* ``async-buffer`` — FedBuff-style: no rounds at the device level.  The
  server aggregates every ``buffer_size`` arrivals with
  staleness-discounted weights ``w_i ∝ 1/(1+s_i)^alpha`` (``s_i`` = server
  versions elapsed since the update's dispatch), then immediately
  dispatches that many replacement devices.  Each aggregation is one
  ``SimResult`` row, so ``time_to_accuracy`` compares policies on the same
  virtual clock.

Event ordering is deterministic: the heap is keyed ``(finish_time,
device_id)`` — ties break by device id, never dict order — and arrival
*sets* come from the event queue while all floating-point reductions
(means, merges) run in dispatch/cohort order, keeping the sync special
case bit-exact and cross-``cohort_mode`` runs reproducible.
"""
from __future__ import annotations

import heapq
import inspect
import math
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.federated import server as server_lib
from repro.federated.faults import FaultInjector, ServerKilled
from repro.federated.state import CohortResults, RoundPlan
from repro.federated.system_model import SystemModel

_POLICIES = ("sync", "deadline", "async-buffer")
_STRAGGLER = ("drop", "carry")


@dataclass(frozen=True)
class ScheduleConfig:
    """How the virtual-clock scheduler closes aggregation steps."""

    policy: str = "sync"             # sync | deadline | async-buffer
    deadline_s: float = math.inf     # round budget (deadline policy)
    straggler: str = "drop"          # drop | carry (deadline policy)
    buffer_size: int = 0             # K arrivals per aggregation (async; 0 -> cohort/2)
    staleness_alpha: float = 0.0     # w = 1/(1+s)^alpha; 0 = uniform (bit-exact fedavg)

    def __post_init__(self):
        if self.policy not in _POLICIES:
            raise ValueError(f"unknown schedule policy {self.policy!r}; one of {_POLICIES}")
        if self.straggler not in _STRAGGLER:
            raise ValueError(f"unknown straggler policy {self.straggler!r}; one of {_STRAGGLER}")
        if not self.deadline_s > 0:
            raise ValueError(f"deadline_s must be positive, got {self.deadline_s}")
        if self.staleness_alpha < 0:
            raise ValueError(f"staleness_alpha must be >= 0, got {self.staleness_alpha}")
        if self.buffer_size < 0:
            raise ValueError(f"buffer_size must be >= 0, got {self.buffer_size}")

    @property
    def keeps_in_flight_state(self) -> bool:
        """True when updates may live across aggregation boundaries.

        These policies checkpoint their in-flight jobs through the
        scheduler's ``state_dict`` (meta version >= 2); a pre-durability
        snapshot (meta version 1, no in-flight section) cannot resume under
        them — the runner raises an actionable error instead."""
        return self.policy == "async-buffer" or (
            self.policy == "deadline" and self.straggler == "carry"
        )


def resolve_schedule(
    schedule: Union[str, ScheduleConfig, None], **overrides
) -> ScheduleConfig:
    """Normalize a policy name / config / None into a ScheduleConfig,
    applying any non-None keyword overrides.

    With no explicit policy, the overrides *infer* one — ``deadline_s`` or
    ``straggler`` implies ``deadline``, ``buffer_size`` implies
    ``async-buffer`` — and options that would be silently dead under
    ``sync`` (every override field) raise instead, so e.g.
    ``api.experiment(..., deadline_s=30)`` can never quietly run a barrier
    experiment while the caller believes they measured deadline
    scheduling."""
    kw = {k: v for k, v in overrides.items() if v is not None}
    if schedule is None:
        if "deadline_s" in kw or "straggler" in kw:
            cfg = ScheduleConfig(policy="deadline")
        elif "buffer_size" in kw:
            cfg = ScheduleConfig(policy="async-buffer")
        elif "staleness_alpha" in kw:
            raise ValueError(
                "staleness_alpha has no effect without a straggler-tolerant "
                "policy; pass schedule='deadline' (straggler='carry') or "
                "schedule='async-buffer'"
            )
        else:
            cfg = ScheduleConfig()
    elif isinstance(schedule, ScheduleConfig):
        cfg = schedule
    elif isinstance(schedule, str):
        cfg = ScheduleConfig(policy=schedule)
    else:
        raise TypeError(f"schedule must be a name or ScheduleConfig, got {schedule!r}")
    if cfg.policy == "sync" and kw:
        raise ValueError(
            f"scheduling options {sorted(kw)} have no effect under the "
            "sync policy; pass schedule='deadline' or schedule='async-buffer'"
        )
    return replace(cfg, **kw) if kw else cfg


def feasible_rate_floor(
    system: SystemModel,
    profiles: Sequence[str],
    deadline_s: float,
    *,
    rate_grid: Sequence[float],
    batch: int,
    seq: int,
    local_steps: int,
    bandwidth_mbps: float = 40.0,
) -> float:
    """Smallest grid rate whose predicted slowest-profile round time fits
    the deadline (expected active fraction ``1 - rate``); the max grid rate
    when even that cannot make it.  Feeds
    :meth:`OnlineConfigurator.set_rate_floor` so deadline-mode exploration
    never wastes rounds on rates that guarantee a dropped straggler."""
    grid = sorted(set(float(r) for r in rate_grid))
    if not grid:
        return 0.0
    profs = sorted(set(profiles))
    for r in grid:
        cost = system.cohort_round_cost(
            devices=profs,
            bandwidth_mbps=bandwidth_mbps,
            batch=batch,
            seq=seq,
            local_steps=local_steps,
            peft=True,
            active_fraction=1.0 - r,
            share_fraction=1.0,
        )
        if float(cost.total_time_s.max()) <= deadline_s:
            return r
    return grid[-1]


@dataclass
class _Job:
    """One in-flight local update: training done eagerly at dispatch (its
    inputs depend only on dispatch-time state), completion deferred to the
    virtual clock."""

    dev: int
    rate: float
    version: int            # server_version at dispatch (staleness base)
    dispatch_round: int
    cohort_pos: int         # position within its dispatch cohort (float order)
    dispatch_time: float
    duration: float         # SystemModel total_time_s
    finish: float           # absolute virtual completion time
    peft: Any
    metrics: dict
    importance: Any
    accuracy: float
    active_frac: float
    mask: np.ndarray        # (L,) bool share-mask row
    compute_s: float
    comm_s: float
    energy_j: float
    traffic_mb: float
    memory_gb: float
    failed: bool = False    # client dropped mid-round (fault injection)
    uplink_peft: Any = None  # server-side reconstruction (compressed uplink)
    comp: str = ""          # compression level this uplink used ("" = none)

    @property
    def order_key(self) -> Tuple[int, int]:
        return (self.dispatch_round, self.cohort_pos)


def _tree_finite(tree) -> bool:
    """Host-side check that every leaf of ``tree`` is finite."""
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree))


# the _Job scalar fields that ride the JSON checkpoint manifest, with the
# coercion applied on both the save and load sides (state_dict /
# load_state_dict) so the two can never drift apart
_JOB_SCALARS = (
    ("dev", int), ("rate", float), ("version", int), ("dispatch_round", int),
    ("cohort_pos", int), ("dispatch_time", float), ("duration", float),
    ("finish", float), ("accuracy", float), ("active_frac", float),
    ("compute_s", float), ("comm_s", float), ("energy_j", float),
    ("traffic_mb", float), ("memory_gb", float), ("failed", bool),
    ("comp", str),
)

# fields absent from older (pre-compression, meta v2) job records load at
# these defaults instead of KeyError-ing the resume
_JOB_SCALAR_DEFAULTS = {"comp": ""}


class VirtualClockScheduler:
    """Drives one :class:`~repro.federated.runner.ExperimentRunner`'s round
    loop through the configured scheduling policy.

    One ``SimResult`` row per aggregation step for every policy, so time
    axes (``cum_time_s`` = the virtual clock) are directly comparable.
    ``event_log`` records every arrival as ``(round_index, device,
    finish_time)`` in event order — the determinism suite asserts it is
    identical across runs and across batched/sequential cohort modes.
    """

    def __init__(
        self,
        runner,
        cfg: Optional[ScheduleConfig] = None,
        faults: Optional[FaultInjector] = None,
    ):
        self.runner = runner
        self.cfg = cfg or getattr(runner, "schedule", None) or ScheduleConfig()
        self.faults = faults
        self.event_log: List[Tuple[int, int, float]] = []
        self.fault_log: List[dict] = []            # rejected updates + billing
        self._heap: List[Tuple[float, int]] = []   # (finish_time, dev)
        self._jobs: Dict[int, _Job] = {}
        self._backoff: Dict[int, float] = {}       # dev -> earliest re-dispatch t
        self._fail_count: Dict[int, int] = {}      # dev -> consecutive failures

    # ------------------------------------------------------------ public api
    @property
    def in_flight(self) -> frozenset:
        return frozenset(self._jobs)

    def run(self, rounds: Optional[int] = None, target_accuracy: Optional[float] = None):
        runner = self.runner
        total = rounds or runner.ctx.fed_cfg.rounds
        step = {
            "sync": self._sync_round,
            "deadline": self._deadline_round,
            "async-buffer": self._async_step,
        }[self.cfg.policy]
        if self.faults is not None and self.cfg.policy == "sync":
            # the barrier path has no dispatch/arrival machinery to inject
            # into; an infinite-deadline drop round is bit-identical to sync
            # (test_schedule_parity) and routes every completion through the
            # fault-aware event loop
            step = self._deadline_round
        while runner.state.round_index < total:
            row = step(total, target_accuracy)
            hit_target = (
                target_accuracy is not None and row["acc"] >= target_accuracy
            )
            if runner.checkpoint_dir and (
                runner.state.round_index % runner.checkpoint_every == 0
                or runner.state.round_index == total
                or hit_target
            ):
                runner.save_checkpoint()
            if self.faults is not None and self.faults.kills_after(
                runner.state.round_index
            ):
                # the crash-restart drill: the checkpoint (if configured)
                # is already durably renamed into place
                raise ServerKilled(
                    f"fault plan kills the server after round "
                    f"{runner.state.round_index}; rebuild the runner with "
                    "resume=True to continue from the newest checkpoint"
                )
            if hit_target:
                break
        return runner.result()

    # ------------------------------------------------------------- sync path
    def _sync_round(self, total: int, target: Optional[float] = None) -> dict:
        """Today's barrier round, hook for hook — the bit-parity anchor."""
        runner, algo = self.runner, self.runner.algorithm
        state = runner.state
        plan = algo.configure_round(state)
        plan.start_pefts = [algo.client_init(state, dev) for dev in plan.cohort]
        state, results = algo.cohort_step(state, plan)
        state, results = algo.compress_uplink(state, results)
        state = algo.aggregate(state, results)
        state, row = algo.report(state, results)
        t0 = runner.state.cum_time
        state = replace(
            state,
            round_index=state.round_index + 1,
            history=state.history + (row,),
            virtual_time=state.cum_time,
            server_version=state.server_version + 1,
        )
        runner.state = state
        # log arrivals in event order for the determinism suite
        times = np.asarray(results.cost.total_time_s).tolist()
        for t, dev in sorted(
            zip(times, plan.cohort), key=lambda p: (p[0], p[1])
        ):
            self.event_log.append((plan.round_index, dev, t0 + t))
        return row

    # ------------------------------------------------------------- dispatch
    def _configure_round(self, algo, state, size: Optional[int]) -> RoundPlan:
        """Call ``configure_round`` with the scheduling kwargs when the
        algorithm accepts them; a pre-scheduler subclass that overrides the
        hook with the old one-argument signature still works whenever no
        kwarg is actually needed (sync and deadline-drop), and gets an
        actionable error instead of a bare TypeError otherwise."""
        excl = self._dispatch_exclusions()
        params = inspect.signature(algo.configure_round).parameters
        accepts_kwargs = any(
            p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
        ) or ("size" in params and "exclude" in params)
        if accepts_kwargs:
            return algo.configure_round(state, size=size, exclude=excl)
        if size is None and not excl:
            return algo.configure_round(state)
        raise TypeError(
            f"{type(algo).__name__}.configure_round(state) must accept "
            f"size=/exclude= keyword arguments to run under the "
            f"{self.cfg.policy!r} policy with in-flight updates — see "
            "FederatedAlgorithm.configure_round"
        )

    def _dispatch_exclusions(self) -> frozenset:
        """Devices that cannot be dispatched at the current virtual time:
        in flight, backing off after a fault, or churned out of the
        population.  Expired backoffs are purged here, so a recovered
        device re-enters the pool exactly at its retry instant."""
        if self.faults is None:
            return self.in_flight
        t = self.runner.state.virtual_time
        for dev in [d for d, ready in self._backoff.items() if ready <= t]:
            del self._backoff[dev]
        excl = set(self._jobs) | set(self._backoff)
        for dev in range(self.runner.ctx.fed_cfg.num_devices):
            if self.faults.unavailable(dev, t):
                excl.add(dev)
        return frozenset(excl)

    def _next_available_time(self, t: float) -> Optional[float]:
        """Earliest virtual instant strictly after ``t`` when a currently
        excluded device becomes dispatchable (backoff expiry or churn
        rejoin), or None when no such instant exists.  The deadline-aware
        fallback idle-advances the clock here instead of stalling when a
        faulted cohort leaves nothing dispatchable and nothing in flight."""
        times = [ready for ready in self._backoff.values() if ready > t]
        if self.faults is not None:
            for dev in range(self.runner.ctx.fed_cfg.num_devices):
                if dev in self._jobs:
                    continue
                rejoin = self.faults.next_rejoin(dev, t)
                if rejoin is not None and rejoin > t:
                    times.append(rejoin)
        return min(times) if times else None

    def _inject_dispatch_faults(self, job: _Job) -> None:
        """Mutate a freshly-dispatched job per the fault plan: stretch its
        uplink (bandwidth collapse), truncate it at the dropout instant
        (partial work billed, update lost), or corrupt its update to NaN.
        Only the virtual-clock trajectory and billing change — the
        training RNG streams are untouched, so devices unaffected by any
        fault compute bit-identical updates."""
        inj = self.faults
        r, dev = job.dispatch_round, job.dev
        bw = inj.bandwidth_factor_at(r, dev)
        if bw > 1.0:
            extra = job.comm_s * (bw - 1.0)
            job.comm_s *= bw
            job.duration += extra
            self.fault_log.append(
                {
                    "round": r,
                    "dev": dev,
                    "reason": "bandwidth-collapse",
                    "time": job.dispatch_time,
                    "slowdown": bw,
                }
            )
        frac = inj.dropout_at(r, dev)
        if frac is not None:
            # the client vanishes after completing `frac` of its round: all
            # billed quantities scale down, the update never arrives intact
            job.failed = True
            job.duration *= frac
            job.compute_s *= frac
            job.comm_s *= frac
            job.energy_j *= frac
            job.traffic_mb *= frac
        if inj.corrupts(r, dev):
            job.peft = jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), job.peft)
            if job.uplink_peft is not None:
                job.uplink_peft = jax.tree.map(
                    lambda x: jnp.full_like(x, jnp.nan), job.uplink_peft
                )
        job.finish = job.dispatch_time + job.duration

    def _dispatch(self, size: Optional[int] = None) -> Tuple[Optional[RoundPlan], List[_Job]]:
        """Sample + train a cohort at the current virtual time and push its
        completion events.  Cost accounting goes through the algorithm's
        ``round_cost`` — the same method the synchronous ``report`` uses —
        so deadline with an infinite budget stays bit-identical to sync."""
        runner, algo = self.runner, self.runner.algorithm
        state = runner.state
        plan = self._configure_round(algo, state, size)
        if not plan.cohort:
            return None, []
        plan.start_pefts = [algo.client_init(state, dev) for dev in plan.cohort]
        state, results = algo.cohort_step(state, plan)
        state, results = algo.compress_uplink(state, results)
        results.masks = algo.compute_masks(state, results)
        cost, active_fracs = algo.round_cost(state, results)
        t0 = state.virtual_time
        # pull each cost vector to python floats once; per-field float(x[i])
        # reads inside the job loop would cost one conversion per element
        rates = [float(r) for r in plan.rates]
        total_s = np.asarray(cost.total_time_s).tolist()
        compute_s = np.asarray(cost.compute_time_s).tolist()
        comm_s = np.asarray(cost.comm_time_s).tolist()
        energy_j = np.asarray(cost.energy_j).tolist()
        traffic_mb = np.asarray(cost.traffic_mb).tolist()
        memory_gb = np.asarray(cost.memory_gb).tolist()
        jobs = []
        for i, dev in enumerate(plan.cohort):
            job = _Job(
                dev=dev,
                rate=rates[i],
                version=state.server_version,
                dispatch_round=plan.round_index,
                cohort_pos=i,
                dispatch_time=t0,
                duration=total_s[i],
                finish=t0 + total_s[i],
                peft=results.pefts[i],
                metrics=results.metrics[i],
                importance=results.importances[i],
                accuracy=results.accuracies[i],
                active_frac=active_fracs[i],
                mask=np.asarray(results.masks[i]),
                compute_s=compute_s[i],
                comm_s=comm_s[i],
                energy_j=energy_j[i],
                traffic_mb=traffic_mb[i],
                memory_gb=memory_gb[i],
                uplink_peft=(
                    results.uplink_pefts[i]
                    if results.uplink_pefts is not None
                    else None
                ),
                comp=plan.compression[i] if plan.compression else "",
            )
            if self.faults is not None:
                self._inject_dispatch_faults(job)
            jobs.append(job)
            self._jobs[dev] = job
            heapq.heappush(self._heap, (job.finish, dev))
        runner.state = state  # key/global_step advanced by cohort_step
        return plan, jobs

    def _pop_arrivals_until(self, close_t: float, round_index: int) -> List[_Job]:
        """Pop every event with ``finish <= close_t`` in (finish, dev) order."""
        arrived = []
        while self._heap and self._heap[0][0] <= close_t:
            finish, dev = heapq.heappop(self._heap)
            job = self._jobs.pop(dev)
            arrived.append(job)
            self.event_log.append((round_index, dev, finish))
        return arrived

    def _pop_k_arrivals(self, k: int, round_index: int) -> List[_Job]:
        arrived = []
        for _ in range(min(k, len(self._heap))):
            finish, dev = heapq.heappop(self._heap)
            job = self._jobs.pop(dev)
            arrived.append(job)
            self.event_log.append((round_index, dev, finish))
        return arrived

    def _screen(self, arrived: List[_Job], round_index: int) -> List[_Job]:
        """Graceful-degradation gate between arrival and aggregation.

        Partitions arrivals into accepted and rejected: a dropped client
        never delivered its update, and a delivered-but-non-finite update
        is screened out before it can poison the global PEFT.  Rejected
        work stays billed (the compute was burned — ``_row`` bills every
        dispatched job), the rejection is recorded in ``fault_log``, and a
        dropped device re-enters the dispatch pool only after an
        exponential virtual-time backoff.  With no injector attached this
        is the identity, and with a zero-fault plan no job is ever
        rejected — both bit-transparent."""
        if self.faults is None:
            return arrived
        ok = []
        for job in sorted(arrived, key=lambda j: j.order_key):
            if job.failed:
                reason = "dropout"
            elif not _tree_finite(
                job.peft if job.uplink_peft is None else job.uplink_peft
            ):
                reason = "non-finite-update"
            else:
                self._fail_count.pop(job.dev, None)
                ok.append(job)
                continue
            entry = {
                "round": round_index,
                "dev": job.dev,
                "reason": reason,
                "time": job.finish,
                "burned_compute_s": job.compute_s,
                "burned_energy_j": job.energy_j,
            }
            if reason == "dropout":
                n = self._fail_count.get(job.dev, 0) + 1
                self._fail_count[job.dev] = n
                retry_at = job.finish + self.faults.backoff_s(n)
                self._backoff[job.dev] = retry_at
                entry["retry_after"] = retry_at
            self.fault_log.append(entry)
        return ok

    # ----------------------------------------------------------- aggregation
    def _aggregate_arrivals(self, arrived: List[_Job], adaopt_depth: int):
        """Apply the algorithm's aggregation to an arrival set, in dispatch
        order (floating-point reductions must not depend on event order),
        with staleness-discounted weights when configured."""
        runner, algo = self.runner, self.runner.algorithm
        state = runner.state
        if not arrived:
            return state, None
        arrived = sorted(arrived, key=lambda j: j.order_key)
        results = CohortResults(
            plan=RoundPlan(
                round_index=state.round_index,
                cohort=[j.dev for j in arrived],
                rates=[j.rate for j in arrived],
                adaopt_depth=adaopt_depth,
                compression=(
                    [j.comp or "none" for j in arrived]
                    if any(j.comp for j in arrived)
                    else None
                ),
            ),
            pefts=[j.peft for j in arrived],
            metrics=[j.metrics for j in arrived],
            importances=[j.importance for j in arrived],
            accuracies=[j.accuracy for j in arrived],
            masks=np.stack([j.mask for j in arrived]),
        )
        if any(j.uplink_peft is not None for j in arrived):
            results.uplink_pefts = [
                j.uplink_peft if j.uplink_peft is not None else j.peft
                for j in arrived
            ]
        staleness = np.array(
            [state.server_version - j.version for j in arrived], dtype=np.int64
        )
        results.staleness = staleness
        if self.cfg.staleness_alpha > 0:
            results.weights = server_lib.staleness_weights(
                staleness, self.cfg.staleness_alpha
            )
        return algo.aggregate(state, results), results

    def _feedback_and_prev_acc(self, state, fb_results, realized, arrived):
        """Reward the configurator with *realized* virtual-clock times and
        advance prev_acc for incorporated updates only."""
        algo = self.runner.algorithm
        algo.feedback(state, fb_results, realized)
        prev_acc = dict(state.prev_acc)
        for job in arrived:
            prev_acc[job.dev] = job.accuracy
        return prev_acc

    # --------------------------------------------------------- deadline path
    def _deadline_round(self, total: int, target: Optional[float] = None) -> dict:
        runner, algo, ctx = self.runner, self.runner.algorithm, self.runner.ctx
        cfg = self.cfg
        t0 = runner.state.virtual_time
        round_index = runner.state.round_index
        plan, jobs = self._dispatch()

        while not self._jobs:
            # deadline-aware fallback: every device is backing off or
            # churned out and nothing is in flight — idle-advance the
            # virtual clock to the next availability instant instead of
            # stalling the queue
            nxt = self._next_available_time(runner.state.virtual_time)
            if nxt is None:
                raise RuntimeError(
                    "deadline scheduler has no dispatchable devices and nothing "
                    "in flight — num_devices is too small for the carry backlog"
                )
            runner.state = replace(runner.state, virtual_time=nxt)
            t0 = nxt
            plan, jobs = self._dispatch()
        state = runner.state
        # close the window: min(deadline, everyone-done), never before the
        # first arrival (a too-tight deadline must still make progress)
        max_fin = max(j.finish for j in self._jobs.values())
        close_t = max_fin
        if math.isfinite(cfg.deadline_s):
            close_t = min(close_t, t0 + cfg.deadline_s)
        min_fin = min(j.finish for j in self._jobs.values())
        close_t = max(close_t, min_fin)
        arrived = self._pop_arrivals_until(close_t, round_index)
        if cfg.straggler == "drop":
            # cut-off updates are discarded; their devices free up next round
            self._heap.clear()
            self._jobs.clear()
        ok = self._screen(arrived, round_index)

        arrived_devs = {j.dev for j in ok}
        state, agg_results = self._aggregate_arrivals(
            ok, plan.adaopt_depth if plan else ctx.cfg.num_layers
        )

        if cfg.straggler == "carry":
            # carried updates are never lost, so bandit feedback waits for
            # the landing: every accepted arrival (on-time or late) reports
            # its full realized duration and trained accuracy — a slow
            # low-dropout arm whose carried updates drive gains is
            # credited, not zeroed.  agg_results already holds the
            # arrivals in dispatch order (its plan cohort/rates match the
            # durations below).  Rejected arrivals carry no usable update
            # and no trained accuracy, so they give the bandit nothing.
            if agg_results is not None:
                ordered = sorted(ok, key=lambda j: j.order_key)
                prev_acc = self._feedback_and_prev_acc(
                    state,
                    agg_results,
                    np.asarray([j.duration for j in ordered], dtype=np.float64),
                    ok,
                )
            else:  # every arrival this window was screened out
                prev_acc = state.prev_acc
        else:
            # drop frees every device each round, so a dispatch plan always
            # exists; feedback covers this round's *dispatched* cohort —
            # arrivals report their realized duration; cut-off stragglers
            # report the deadline they burned and a zero accuracy gain
            # (their update went nowhere)
            assert plan is not None
            chance = 1.0 / ctx.task.num_classes
            fb_accs, realized = [], []
            for job in jobs:
                if job.dev in arrived_devs and job.dispatch_round == round_index:
                    fb_accs.append(job.accuracy)
                    realized.append(job.duration)
                else:
                    fb_accs.append(state.prev_acc.get(job.dev, chance))
                    realized.append(min(job.duration, cfg.deadline_s))
            fb_results = CohortResults(
                plan=plan,
                pefts=[j.peft for j in jobs],
                metrics=[j.metrics for j in jobs],
                importances=[j.importance for j in jobs],
                accuracies=fb_accs,
                masks=np.stack([j.mask for j in jobs]),
            )
            prev_acc = self._feedback_and_prev_acc(
                state, fb_results, np.asarray(realized, dtype=np.float64), ok
            )

        row = self._row(
            close_t,
            arrived=sorted(ok, key=lambda j: j.order_key),
            dispatched=jobs,
        )
        state = replace(
            state,
            cum_time=close_t,
            virtual_time=close_t,
            server_version=state.server_version + 1,
            prev_acc=prev_acc,
            round_index=state.round_index + 1,
            history=state.history + (row,),
        )
        runner.state = state
        return row

    # ------------------------------------------------------------ async path
    def _async_step(self, total: int, target: Optional[float] = None) -> dict:
        runner, ctx = self.runner, self.runner.ctx
        fed = ctx.fed_cfg
        if not self._jobs:
            # prime the pipeline: fill concurrency = devices_per_round
            self._dispatch(size=fed.devices_per_round)
        while not self._jobs:
            # deadline-aware fallback, async flavor: the whole population
            # is backing off or churned out — idle-advance the virtual
            # clock to the next availability instant and re-prime
            nxt = self._next_available_time(runner.state.virtual_time)
            if nxt is None:
                raise RuntimeError("async scheduler drained its event queue")
            runner.state = replace(runner.state, virtual_time=nxt)
            self._dispatch(size=fed.devices_per_round)
        k = self.cfg.buffer_size or max(1, fed.devices_per_round // 2)
        round_index = runner.state.round_index
        arrived = self._pop_k_arrivals(k, round_index)
        if not arrived:
            raise RuntimeError("async scheduler drained its event queue")
        close_t = max(j.finish for j in arrived)  # heap pops are monotone
        ok = self._screen(arrived, round_index)

        state, agg_results = self._aggregate_arrivals(ok, ctx.cfg.num_layers)
        ordered = sorted(ok, key=lambda j: j.order_key)
        if agg_results is not None:
            realized = np.asarray([j.duration for j in ordered], dtype=np.float64)
            prev_acc = self._feedback_and_prev_acc(state, agg_results, realized, ok)
        else:  # the whole buffer was screened out — aggregate nothing
            prev_acc = state.prev_acc
        row = self._row(
            close_t,
            arrived=ordered,
            dispatched=sorted(arrived, key=lambda j: j.order_key),
        )
        if agg_results is not None:
            row["staleness"] = float(np.mean(agg_results.staleness))
        state = replace(
            state,
            cum_time=close_t,
            virtual_time=close_t,
            server_version=state.server_version + 1,
            prev_acc=prev_acc,
            round_index=state.round_index + 1,
            history=state.history + (row,),
        )
        runner.state = state
        # refill the pipeline with as many devices as just arrived (skip
        # once the aggregation budget is spent or the target accuracy was
        # just reached — no point training a cohort whose updates can never
        # land)
        if state.round_index < total and not (
            target is not None and row["acc"] >= target
        ):
            self._dispatch(size=len(arrived))
        return row

    # --------------------------------------------------------- durable state
    def state_dict(self) -> Tuple[list, dict]:
        """Serializable snapshot of every piece of in-flight state.

        Returns ``(jobs_arrays, meta)``: one array tree per in-flight job
        (PEFT update, metrics, importance, share-mask) aligned with the
        ``meta["jobs"]`` scalar records, plus the event/fault logs and the
        retry bookkeeping.  Scalars ride the JSON manifest (Python's float
        repr round-trips exactly); arrays ride the checkpoint npz path
        with dtypes preserved.  :meth:`load_state_dict` rebuilds a
        scheduler that continues bit-identically: the heap is keyed
        ``(finish, dev)``, so re-``heapify``-ing the rebuilt entries pops
        in exactly the original order regardless of internal arrangement.
        """
        jobs = [self._jobs[dev] for dev in sorted(self._jobs)]
        jobs_arrays, job_meta = [], []
        for j in jobs:
            jobs_arrays.append(
                {
                    "peft": j.peft,
                    "metrics": j.metrics,
                    "importance": j.importance if j.importance is not None else [],
                    "mask": j.mask,
                    "uplink_peft": j.uplink_peft if j.uplink_peft is not None else [],
                }
            )
            record = {
                name: cast(getattr(j, name)) for name, cast in _JOB_SCALARS
            }
            record["has_importance"] = j.importance is not None
            record["has_uplink"] = j.uplink_peft is not None
            job_meta.append(record)
        meta = {
            "jobs": job_meta,
            "event_log": [[int(r), int(d), float(t)] for r, d, t in self.event_log],
            "fault_log": list(self.fault_log),
            "backoff": {str(k): float(v) for k, v in self._backoff.items()},
            "fail_count": {str(k): int(v) for k, v in self._fail_count.items()},
        }
        return jobs_arrays, meta

    def load_state_dict(self, jobs_arrays: list, meta: dict) -> None:
        """Rebuild in-flight state saved by :meth:`state_dict`."""
        self._jobs.clear()
        self._heap = []
        for arrs, jm in zip(jobs_arrays, meta["jobs"]):
            # jm holds JSON scalars (never device arrays); the shared field
            # table keeps save/load coercions from drifting apart
            scalars = {
                name: cast(jm[name]) if name in jm else _JOB_SCALAR_DEFAULTS[name]
                for name, cast in _JOB_SCALARS
            }
            job = _Job(
                peft=jax.tree.map(jnp.asarray, arrs["peft"]),
                metrics=arrs["metrics"],
                importance=arrs["importance"] if jm["has_importance"] else None,
                mask=np.asarray(arrs["mask"]),
                uplink_peft=(
                    jax.tree.map(jnp.asarray, arrs["uplink_peft"])
                    if jm.get("has_uplink", False)
                    else None
                ),
                **scalars,
            )
            self._jobs[job.dev] = job
            self._heap.append((job.finish, job.dev))
        heapq.heapify(self._heap)
        self.event_log = [
            (int(r), int(d), float(t)) for r, d, t in meta.get("event_log", [])
        ]
        self.fault_log = list(meta.get("fault_log", []))
        self._backoff = {
            int(k): float(v) for k, v in meta.get("backoff", {}).items()
        }
        self._fail_count = {
            int(k): int(v) for k, v in meta.get("fail_count", {}).items()
        }

    # ------------------------------------------------------------------ rows
    def _row(self, close_t, *, arrived: List[_Job], dispatched: List[_Job]) -> dict:
        """One SimResult history row.

        Accuracy/loss describe what the server aggregated (arrivals);
        rate/active/traffic/energy/memory bill the work dispatched this
        step.  A deadline-*drop* straggler burned only the window, not its
        full round: its energy/traffic are billed pro-rata to the time it
        actually spent before the cut (matching the deadline-capped time
        the bandit sees).  Carried stragglers complete later, so their
        dispatch row bills the full job.  In the sync special case both
        sets coincide, every job finishes inside the window (pro-rata
        factor exactly 1.0), and every reduction runs in cohort order,
        reproducing the barrier row bit-for-bit.
        """
        cut = self.cfg.policy == "deadline" and self.cfg.straggler == "drop"

        def _frac(j: _Job) -> float:
            if not cut or j.finish <= close_t:
                return 1.0
            return max(close_t - j.dispatch_time, 0.0) / j.duration

        if arrived:
            acc = float(np.mean([j.accuracy for j in arrived]))
            loss = float(
                np.mean(
                    np.asarray(
                        jax.device_get([j.metrics["loss"] for j in arrived]),
                        dtype=np.float64,
                    )
                )
            )
        else:  # nothing incorporated: carry the previous row's curve values
            hist = self.runner.state.history
            acc = float(hist[-1]["acc"]) if hist else 0.0
            loss = float(hist[-1]["loss"]) if hist else 0.0
        # only dispatch-time work is billed; a carry round that dispatched
        # nothing (all devices in flight) bills zero — its arrivals were
        # already billed in full at their own dispatch rounds
        billed = dispatched
        return {
            "time": close_t,
            "acc": acc,
            "loss": loss,
            "rate": float(np.mean([j.rate for j in billed])) if billed else 0.0,
            "active": float(np.mean([j.active_frac for j in billed])) if billed else 0.0,
            "traffic": float(np.sum([j.traffic_mb * _frac(j) for j in billed])) if billed else 0.0,
            "energy": float(np.sum([j.energy_j * _frac(j) for j in billed])) if billed else 0.0,
            "memory": float(np.max([j.memory_gb for j in billed])) if billed else 0.0,
            "arrivals": len(arrived),
        }
