"""Engine-agnostic experiment runner: the federated round loop.

:class:`ExperimentRunner` owns the loop that used to live inside the
``FederatedSimulator`` god-class.  It builds an :class:`ExperimentContext`
(task, device shards, hardware profiles, system model, execution engine),
binds a :class:`~repro.federated.algorithms.FederatedAlgorithm`, and drives
its lifecycle hooks round by round, threading an immutable
:class:`~repro.federated.state.RoundState` through them.

On top of the plain loop it provides what the god-class could not:

* ``target_accuracy`` early stop (unchanged semantics),
* save/resume — any round boundary can be checkpointed through
  :mod:`repro.checkpoint` and resumed bit-exactly (PRNG streams, bandit
  arms, per-device data-sampler states and metric history included),
* multi-seed replication via :func:`run_replicates`.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.core import peft as peft_lib
from repro.data import DeviceDataset, dirichlet_partition, make_task
from repro.federated.algorithms import FederatedAlgorithm, get_algorithm
from repro.federated.compression import CompressionConfig, resolve_compression
from repro.federated.engine import CohortEngine
from repro.federated.faults import FaultInjector, resolve_fault_plan
from repro.federated.scheduler import (
    ScheduleConfig,
    VirtualClockScheduler,
    resolve_schedule,
)
from repro.federated.state import RoundState
from repro.federated.system_model import SystemModel, sample_device
from repro.models import stacking
from repro.models.registry import init_params


@dataclass
class SimResult:
    rounds: int
    cum_time_s: np.ndarray           # (R,) scheduler virtual clock at each aggregation
    accuracy: np.ndarray             # (R,) mean val accuracy of aggregated updates
    loss: np.ndarray                 # (R,)
    rates: np.ndarray                # (R,) mean dropout rate used
    active_fraction: np.ndarray      # (R,) measured E[L~]/L
    traffic_mb: np.ndarray           # (R,) cohort total
    energy_j: np.ndarray             # (R,) cohort total
    memory_gb: np.ndarray            # (R,) max per-device footprint
    final_accuracy: float = 0.0
    arrivals: Optional[np.ndarray] = None  # (R,) updates aggregated per step

    def time_to_accuracy(self, target: float, *, sustained: bool = False) -> Optional[float]:
        """Simulated time until ``accuracy >= target``.

        ``sustained=True`` requires the target to be held for every later
        round too (suffix minimum), so a single noisy round that dips back
        below the target cannot win a speedup claim.
        """
        if sustained:
            suffix_min = np.minimum.accumulate(self.accuracy[::-1])[::-1]
            hit = np.where(suffix_min >= target)[0]
        else:
            hit = np.where(self.accuracy >= target)[0]
        return float(self.cum_time_s[hit[0]]) if len(hit) else None


@dataclass
class ExperimentContext:
    """Everything an algorithm's hooks may consult; built once per seed."""

    cfg: Any
    peft_cfg: Any
    stld_cfg: Any
    fed_cfg: Any
    train_cfg: Any
    task: Any
    devices: List[DeviceDataset]
    device_profile: List[str]
    system: SystemModel
    seed: int
    peft_key: Any                  # the key init_peft consumed (hetlora re-init)
    init_global_peft: Any
    num_classes: Any               # jnp.arange(task.num_classes)
    engine: Optional[CohortEngine] = None
    schedule: Optional[ScheduleConfig] = None  # virtual-clock scheduling policy
    compression: Optional[CompressionConfig] = None  # uplink compression | None


def _build_context(
    cfg, peft_cfg, stld_cfg, fed_cfg, train_cfg, *, task=None, cost_cfg=None, seed=0,
    device_profile=None,
):
    """Replicates the legacy simulator's construction order exactly so the
    numpy/JAX RNG streams (device profiles, param init) are unchanged.

    ``device_profile`` (optional) pins the hardware mix instead of sampling
    it — benchmarks and golden tests use it to build e.g. a guaranteed
    mixed tx2/nx/agx cohort.  Pinning skips the profile RNG draws, so a
    pinned run is not stream-comparable with a sampled one.
    """
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    task = task or make_task(vocab_size=cfg.vocab_size, seed=seed)
    parts = dirichlet_partition(
        task.labels, fed_cfg.num_devices, fed_cfg.dirichlet_alpha, seed=seed
    )
    devices = [DeviceDataset(task, idx, seed=seed + i) for i, idx in enumerate(parts)]
    if device_profile is None:
        device_profile = [sample_device(rng) for _ in range(fed_cfg.num_devices)]
    else:
        device_profile = list(device_profile)
        if len(device_profile) != fed_cfg.num_devices:
            raise ValueError(
                f"device_profile has {len(device_profile)} entries for "
                f"{fed_cfg.num_devices} devices"
            )
    key, k1, k2 = jax.random.split(key, 3)
    base_params = init_params(k1, cfg)
    global_peft = peft_lib.init_peft(k2, cfg, peft_cfg)
    ctx = ExperimentContext(
        cfg=cfg,
        peft_cfg=peft_cfg,
        stld_cfg=stld_cfg,
        fed_cfg=fed_cfg,
        train_cfg=train_cfg,
        task=task,
        devices=devices,
        device_profile=device_profile,
        system=SystemModel(cost_cfg or cfg, peft_cfg),
        seed=seed,
        peft_key=k2,
        init_global_peft=global_peft,
        num_classes=jnp.arange(task.num_classes),
    )
    return ctx, rng, key, base_params


def fresh_algorithm(algorithm):
    """Per-run copy of an algorithm prototype, configuration preserved.

    Algorithm instances are bound to one experiment context; reusing one
    across runners would rebind it and mutate the caller's object.  A
    shallow copy keeps every constructor-configured attribute (ranks,
    fixed rates, toggles) while ``bind`` recomputes all derived state.
    """
    if isinstance(algorithm, str):
        return algorithm
    algo = copy.copy(algorithm)
    algo.ctx = None
    return algo


class ExperimentRunner:
    """Round loop + state threading + checkpointing for one experiment."""

    def __init__(
        self,
        cfg,
        peft_cfg,
        stld_cfg,
        fed_cfg,
        train_cfg,
        *,
        algorithm: "FederatedAlgorithm | str" = "droppeft",
        task=None,
        cost_cfg=None,
        seed: int = 0,
        cohort_mode: str = "auto",
        schedule: "ScheduleConfig | str" = "sync",
        device_profile=None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 1,
        resume: bool = False,
        fault_plan=None,
        compression=None,
    ):
        if isinstance(algorithm, str):
            algorithm = get_algorithm(algorithm)()
        else:
            # never bind a caller-owned instance: a second runner built from
            # the same prototype would silently rebind its context
            algorithm = fresh_algorithm(algorithm)
        self.algorithm = algorithm
        self.schedule = resolve_schedule(schedule)
        self.compression = resolve_compression(compression)
        self.fault_plan = resolve_fault_plan(fault_plan)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = max(1, checkpoint_every)

        ctx, rng, key, base_params = _build_context(
            cfg, peft_cfg, stld_cfg, fed_cfg, train_cfg,
            task=task, cost_cfg=cost_cfg, seed=seed, device_profile=device_profile,
        )
        ctx.schedule = self.schedule  # visible to bind()/build_configurator
        ctx.compression = self.compression
        self.ctx = ctx
        global_peft = algorithm.bind(ctx)

        if cohort_mode not in ("auto", "batched", "sequential"):
            raise ValueError(f"unknown cohort_mode {cohort_mode!r}")
        if cohort_mode == "batched" and algorithm.requires_sequential:
            raise ValueError(
                f"cohort_mode='batched' cannot stack {algorithm.name}'s "
                "heterogeneous PEFT trees; use 'sequential' (or 'auto')"
            )
        if cohort_mode == "auto":
            cohort_mode = "sequential" if algorithm.requires_sequential else "batched"
        self.cohort_mode = cohort_mode

        ctx.engine = CohortEngine(
            cfg, peft_cfg, stld_cfg, fed_cfg, train_cfg, ctx.task, ctx.devices,
            base_params, cohort_mode=cohort_mode, stld_enabled=algorithm.stld,
        )
        if getattr(algorithm, "device_rank", None) is not None:
            ctx.engine.enable_hetlora(algorithm.device_rank)

        self.state = RoundState(
            key=key,
            global_peft=global_peft,
            rng=rng,
            configurator=algorithm.build_configurator(ctx),
        )
        self.scheduler = VirtualClockScheduler(
            self,
            self.schedule,
            faults=(
                FaultInjector(self.fault_plan)
                if self.fault_plan is not None
                else None
            ),
        )
        if resume:
            if not checkpoint_dir:
                raise ValueError("resume=True requires checkpoint_dir")
            self._restore_latest()

    # ---------------------------------------------------------------- loop
    def run(
        self, rounds: Optional[int] = None, target_accuracy: Optional[float] = None
    ) -> SimResult:
        """Drive the round loop through the virtual-clock scheduler.

        The scheduler owns the loop for every policy; ``schedule="sync"``
        calls the lifecycle hooks in the exact pre-scheduler order, so its
        results are bit-identical to the historical barrier loop
        (``tests/test_schedule_parity.py``)."""
        return self.scheduler.run(rounds=rounds, target_accuracy=target_accuracy)

    def result(self) -> SimResult:
        hist = self.state.history
        res = SimResult(
            rounds=len(hist),
            cum_time_s=np.asarray([r["time"] for r in hist]),
            accuracy=np.asarray([r["acc"] for r in hist]),
            loss=np.asarray([r["loss"] for r in hist]),
            rates=np.asarray([r["rate"] for r in hist]),
            active_fraction=np.asarray([r["active"] for r in hist]),
            traffic_mb=np.asarray([r["traffic"] for r in hist]),
            energy_j=np.asarray([r["energy"] for r in hist]),
            memory_gb=np.asarray([r["memory"] for r in hist]),
            arrivals=np.asarray([r.get("arrivals", -1) for r in hist]),
        )
        res.final_accuracy = self.ctx.engine.final_accuracy(
            self.state.global_peft, self.state.device_peft, self.ctx.num_classes
        )
        return res

    # --------------------------------------------------------- checkpointing
    # Checkpoint meta versions:
    #   1 (implicit; pre-durability) — round state only, no in-flight
    #     scheduler section.  Still loads under policies that never keep
    #     updates across aggregation boundaries (sync, deadline+drop).
    #   2 — adds "scheduler" (in-flight jobs, event/fault logs, retry
    #     bookkeeping) + "fault_plan", making async-buffer and
    #     deadline+carry resumable bit-exactly.
    #   3 — adds "ef_residual" (per-device error-feedback residual trees)
    #     plus per-job uplink reconstructions/levels inside the scheduler
    #     section.  v2 snapshots still load (empty residuals, no uplink
    #     state) — they could only have been written by uncompressed runs.
    CKPT_META_VERSION = 3

    def save_checkpoint(self) -> str:
        """Persist the full round state; a resumed run is bit-identical."""
        state = self.state
        sched_jobs, sched_meta = self.scheduler.state_dict()
        arrays = {
            "key": np.asarray(state.key),
            "global_peft": state.global_peft,
            "device_peft": {str(d): t for d, t in sorted(state.device_peft.items())},
            "last_mask": {
                str(d): np.asarray(m) for d, m in sorted(state.last_mask.items())
            },
            "ef_residual": {
                str(d): t for d, t in sorted(state.ef_residual.items())
            },
            "scheduler_jobs": sched_jobs,
        }
        meta = {
            "meta_version": self.CKPT_META_VERSION,
            "scheduler": sched_meta,
            "fault_plan": (
                None if self.fault_plan is None else self.fault_plan.to_json()
            ),
            "round_index": state.round_index,
            "global_step": state.global_step,
            "cum_time": state.cum_time,
            "virtual_time": state.virtual_time,
            "server_version": state.server_version,
            "prev_acc": {str(d): v for d, v in state.prev_acc.items()},
            "rng_state": state.rng.bit_generator.state,
            "device_rng": [d._rng.bit_generator.state for d in self.ctx.devices],
            "configurator": (
                state.configurator.state_dict() if state.configurator else None
            ),
            "history": list(state.history),
        }
        return ckpt_lib.save_state(
            self.checkpoint_dir, state.round_index, arrays, meta
        )

    def _peft_native_layout(self, tree):
        """Convert a checkpointed PEFT tree to this runner's native layout.

        Pre-refactor checkpoints stored per-layer lists; the stacked-native
        runner loads them transparently (and vice versa for heterogeneous
        configs whose native layout is still the list)."""
        native_stacked = stacking.is_stacked(self.ctx.init_global_peft)
        if native_stacked and isinstance(tree, (list, tuple)):
            return stacking.stack_params(list(tree))
        if not native_stacked and stacking.is_stacked(tree):
            return stacking.unstack_params(tree, self.ctx.cfg.num_layers)
        return tree

    def _restore_latest(self):
        latest = ckpt_lib.latest_state_dir(self.checkpoint_dir)
        if latest is None:
            return  # nothing saved yet: fresh start
        arrays, meta = ckpt_lib.load_state(latest)
        state = self.state
        sched_meta = meta.get("scheduler")
        if sched_meta is None and self.schedule.keeps_in_flight_state:
            raise ValueError(
                f"checkpoint at {latest} predates durable in-flight state "
                f"(meta version {meta.get('meta_version', 1)}; this runner "
                f"writes version {self.CKPT_META_VERSION}) and cannot resume "
                f"under policy={self.schedule.policy!r}/straggler="
                f"{self.schedule.straggler!r}, which keeps updates in flight "
                "across aggregation boundaries.  Resume it under "
                "schedule='sync' or deadline+drop, or re-run from scratch to "
                "produce a current-version snapshot."
            )
        if len(meta["device_rng"]) != len(self.ctx.devices):
            raise ValueError(
                f"checkpoint at {latest} was saved with "
                f"{len(meta['device_rng'])} devices but this runner has "
                f"{len(self.ctx.devices)}; resume requires an identical config"
            )
        if (meta["configurator"] is None) != (state.configurator is None):
            raise ValueError(
                f"checkpoint at {latest} disagrees with this runner about the "
                "rate configurator; resume requires the same method/config"
            )
        state.rng.bit_generator.state = meta["rng_state"]
        for dev, rng_state in zip(self.ctx.devices, meta["device_rng"]):
            dev._rng.bit_generator.state = rng_state
        configurator = state.configurator
        if configurator is not None and meta["configurator"] is not None:
            configurator.load_state_dict(meta["configurator"])
        self.state = RoundState(
            key=jnp.asarray(arrays["key"]),
            global_peft=self._peft_native_layout(arrays["global_peft"]),
            device_peft={
                int(d): self._peft_native_layout(t)
                for d, t in arrays["device_peft"].items()
            },
            last_mask={int(d): m for d, m in arrays["last_mask"].items()},
            ef_residual={
                int(d): jax.tree.map(jnp.asarray, t)
                for d, t in arrays.get("ef_residual", {}).items()
            },
            round_index=meta["round_index"],
            global_step=meta["global_step"],
            cum_time=meta["cum_time"],
            # pre-scheduler checkpoints (no virtual clock) resume with
            # virtual_time == cum_time, which is exact for sync rounds
            virtual_time=meta.get("virtual_time", meta["cum_time"]),
            server_version=meta.get("server_version", meta["round_index"]),
            prev_acc={int(d): v for d, v in meta["prev_acc"].items()},
            rng=state.rng,
            configurator=configurator,
            history=tuple(meta["history"]),
        )
        if sched_meta is not None:
            self.scheduler.load_state_dict(
                arrays.get("scheduler_jobs", []), sched_meta
            )


def run_replicates(
    seeds: Sequence[int],
    cfg,
    peft_cfg,
    stld_cfg,
    fed_cfg,
    train_cfg,
    *,
    algorithm="droppeft",
    rounds: Optional[int] = None,
    target_accuracy: Optional[float] = None,
    **runner_kwargs,
) -> List[SimResult]:
    """Multi-seed replication: one independent runner (fresh task partition,
    device profiles, and model init) per seed."""
    results = []
    for seed in seeds:
        runner = ExperimentRunner(
            cfg, peft_cfg, stld_cfg, fed_cfg, train_cfg,
            algorithm=fresh_algorithm(algorithm), seed=seed, **runner_kwargs,
        )
        results.append(runner.run(rounds=rounds, target_accuracy=target_accuracy))
    return results
