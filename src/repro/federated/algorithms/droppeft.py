"""DropPEFT (the paper's method) and its b1/b2/b3 ablations.

DropPEFT = STLD layer dropout during local fine-tuning + the online bandit
dropout-rate configurator (Algorithm 1) + PTLS personalized layer sharing
(Eq. 6 / Fig. 8).  The ablations toggle one component each, mirroring the
paper's ablation study:

    droppeft_b1 — without STLD (dropout off; the bandit is moot)
    droppeft_b2 — without the configurator (fixed dropout rate)
    droppeft_b3 — without PTLS (plain FedAvg aggregation)
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

from repro.core.configurator import JointConfigurator, OnlineConfigurator
from repro.federated import compression as compression_lib
from repro.federated import server as server_lib
from repro.federated.algorithms.base import FederatedAlgorithm, register
from repro.federated.state import CohortResults, RoundState


@register("droppeft")
class DropPEFT(FederatedAlgorithm):
    """STLD + bandit configurator + PTLS (paper §3)."""

    stld = True
    use_configurator = True
    use_ptls = True

    def __init__(
        self,
        *,
        stld: Optional[bool] = None,
        configurator: Optional[bool] = None,
        ptls: Optional[bool] = None,
        fixed_rate: Optional[float] = None,
    ):
        super().__init__()
        if stld is not None:
            self.stld = stld
        if configurator is not None:
            self.use_configurator = configurator
        if ptls is not None:
            self.use_ptls = ptls
        if fixed_rate is not None:
            self.fixed_rate = fixed_rate

    def build_configurator(self, ctx):
        # the bandit only exists when there is a dropout rate to tune
        if not (self.use_configurator and self.stld):
            return None
        fed = ctx.fed_cfg
        comp = getattr(ctx, "compression", None)
        kwargs = dict(
            rate_grid=fed.rate_grid,
            num_candidates=fed.num_candidates,
            explore_rate=fed.explore_rate,
            explore_interval=fed.explore_interval,
            window_size=fed.window_size,
            seed=ctx.seed,
        )
        if comp is not None and comp.tune:
            # joint (dropout rate × compression level) arm space; rewards
            # come from the realized virtual-clock round times, which
            # already reflect the compressed uplink billing
            cfgor = JointConfigurator(levels=compression_lib.LEVELS, **kwargs)
        else:
            cfgor = OnlineConfigurator(**kwargs)
        # deadline-aware mode: dropout ratios the slowest profile can never
        # finish within the round budget are infeasible arms — cap the
        # candidate space at the predicted feasible floor so exploration
        # rounds are not wasted on guaranteed stragglers
        sched = getattr(ctx, "schedule", None)
        if (
            sched is not None
            and sched.policy == "deadline"
            and math.isfinite(sched.deadline_s)
        ):
            from repro.federated.scheduler import feasible_rate_floor

            cfgor.set_rate_floor(
                feasible_rate_floor(
                    ctx.system,
                    ctx.device_profile,
                    sched.deadline_s,
                    rate_grid=fed.rate_grid,
                    batch=fed.batch_size,
                    seq=ctx.task.seq_len,
                    local_steps=fed.local_steps,
                )
            )
        return cfgor

    def client_init(self, state: RoundState, dev: int):
        """Shared layers from the global model; personalized layers local."""
        if dev not in state.device_peft or not self.use_ptls:
            return state.global_peft
        own = state.device_peft[dev]
        mask = state.last_mask.get(dev)
        if mask is None:
            return state.global_peft
        # device keeps its own layers; refresh from global (download)
        if isinstance(state.global_peft, (list, tuple)):
            return [
                state.global_peft[l]
                if bool(mask[l])  # repro-lint: disable=JXH002 — numpy row
                else own[l]
                for l in range(self.ctx.cfg.num_layers)
            ]
        # stacked layout: one jit'd per-layer select, device-resident
        return server_lib.select_layers(np.asarray(mask), state.global_peft, own)

    def compute_masks(self, state: RoundState, results: CohortResults):
        if not self.use_ptls:
            return super().compute_masks(state, results)
        fed, cfg = self.ctx.fed_cfg, self.ctx.cfg
        k = max(1, int(fed.ptls_share_fraction * cfg.num_layers))
        importances = np.stack([np.asarray(imp) for imp in results.importances])
        return np.asarray(server_lib.cohort_shared_masks(importances, k))

    def merge(self, state: RoundState, results: CohortResults):
        if not self.use_ptls:
            return super().merge(state, results)
        # async/carry scheduling sets staleness-discount weights; None keeps
        # the bit-exact unweighted PTLS masked mean
        weights = None if results.weights is None else np.asarray(results.weights)
        return self.ctx.engine.ptls_aggregate(
            self._merge_trees(results), results.masks, state.global_peft,
            weights=weights,
        )

    def feedback(self, state: RoundState, results: CohortResults, round_times):
        if state.configurator is None:
            return
        gains = []
        for i, dev in enumerate(results.plan.cohort):
            prev = state.prev_acc.get(dev, 1.0 / self.ctx.task.num_classes)
            gains.append(max(results.accuracies[i] - prev, 0.0))
        cfgor = state.configurator
        if getattr(cfgor, "joint", False) and results.plan.compression is not None:
            arms = list(
                zip(
                    [float(r) for r in results.plan.rates],
                    results.plan.compression,
                )
            )
            cfgor.report(arms, gains, round_times)
        else:
            cfgor.report(results.plan.rates, gains, round_times)


@register("droppeft_b1")
class DropPEFTNoSTLD(DropPEFT):
    """Ablation b1: no layer dropout (and therefore no rate bandit)."""

    stld = False


@register("droppeft_b2")
class DropPEFTFixedRate(DropPEFT):
    """Ablation b2: fixed dropout rate instead of the online configurator."""

    use_configurator = False


@register("droppeft_b3")
class DropPEFTNoPTLS(DropPEFT):
    """Ablation b3: plain FedAvg aggregation instead of PTLS."""

    use_ptls = False
