"""Registered federated algorithms (one subclass per paper method).

Importing this package populates the registry.  Registration order matches
the paper's method table so ``repro.api.list_methods()`` stays stable.
"""
from repro.federated.algorithms.base import (
    FederatedAlgorithm,
    get_algorithm,
    register,
    registered_methods,
)
from repro.federated.algorithms.baselines import (
    FedAdapter,
    FedAdaOPT,
    FedHetLoRA,
    FedLoRA,
)
from repro.federated.algorithms.droppeft import (
    DropPEFT,
    DropPEFTFixedRate,
    DropPEFTNoPTLS,
    DropPEFTNoSTLD,
)

__all__ = [
    "FederatedAlgorithm",
    "register",
    "get_algorithm",
    "registered_methods",
    "FedLoRA",
    "FedAdapter",
    "FedHetLoRA",
    "FedAdaOPT",
    "DropPEFT",
    "DropPEFTNoSTLD",
    "DropPEFTFixedRate",
    "DropPEFTNoPTLS",
]
