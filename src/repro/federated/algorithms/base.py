"""The composable federated-algorithm API: registry + lifecycle hooks.

A federated method is a :class:`FederatedAlgorithm` subclass registered by
name.  The :class:`~repro.federated.runner.ExperimentRunner` owns the round
loop and calls the five lifecycle hooks in a fixed order each round:

    1. ``configure_round(state) -> RoundPlan``   cohort + dropout rates
    2. ``client_init(state, dev) -> peft``       per-device start tree
    3. ``cohort_step(state, plan)``              train the cohort (engine)
    4. ``aggregate(state, results)``             masks + new global model
    5. ``report(state, results)``                costs, bandit feedback, row

Hooks are functional: they take a :class:`~repro.federated.state.RoundState`
and return a new one (plus their hook-specific payload).  The base class
implements the generic FedPEFT loop — uniform cohort sampling, no layer
dropout, FedAvg aggregation — through small overridable policy methods
(``round_rates``, ``active_depth``, ``compute_masks``, ``merge``,
``feedback``), so a new method is typically a ~50-line subclass.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, List, Type

import numpy as np

import jax

from repro.federated import compression as compression_lib
from repro.federated.state import CohortResults, RoundPlan, RoundState
from repro.federated.system_model import sample_bandwidth

_REGISTRY: Dict[str, Type["FederatedAlgorithm"]] = {}


def _trees_congruent(a, b) -> bool:
    """Same treedef and leaf shapes — an EF residual saved for one PEFT
    geometry (e.g. a hetlora rank) must not be reused for another."""
    if jax.tree.structure(a) != jax.tree.structure(b):
        return False
    return all(
        np.shape(la) == np.shape(lb)
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def register(name: str):
    """Class decorator: add a FederatedAlgorithm to the method registry."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_algorithm(name: str) -> Type["FederatedAlgorithm"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown federated method {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def registered_methods() -> List[str]:
    """Registered method names, in registration order."""
    return list(_REGISTRY)


class FederatedAlgorithm:
    """Base algorithm: plain federated PEFT (FedAvg, no dropout, no PTLS)."""

    name = "fedpeft"
    stld = False                 # STLD layer dropout during local training
    use_configurator = False     # online bandit picks the dropout rate
    use_ptls = False             # personalized two-stage layer sharing
    fixed_rate = 0.5             # dropout rate when the bandit is off
    requires_sequential = False  # per-device trees can't share a vmap axis

    def __init__(self):
        self.ctx = None

    # ---------------------------------------------------------------- binding
    def bind(self, ctx):
        """Attach the experiment context; returns the initial global PEFT
        tree (subclasses may re-initialize it, e.g. at a different rank)."""
        self.ctx = ctx
        return ctx.init_global_peft

    def build_configurator(self, ctx):
        """The bandit rate configurator, or None for fixed-policy methods."""
        return None

    # ------------------------------------------------------- lifecycle hooks
    def configure_round(self, state: RoundState, *, size=None, exclude=()) -> RoundPlan:
        """Sample the cohort and pick per-device dropout rates.

        The virtual-clock scheduler passes ``size`` (async-buffer refills
        dispatch as many devices as just arrived) and ``exclude`` (devices
        with an update still in flight cannot be sampled again).  The
        default call — no kwargs — consumes the numpy RNG stream exactly as
        the pre-scheduler loop did, which the sync-parity suite relies on.
        """
        fed = self.ctx.fed_cfg
        want = fed.devices_per_round if size is None else size
        if exclude:
            free = [d for d in range(fed.num_devices) if d not in exclude]
            n = min(want, len(free))
            cohort = [
                int(free[i])  # repro-lint: disable=JXH002 — 'free' is a python list
                for i in state.rng.choice(len(free), size=n, replace=False)
            ]
        else:
            cohort = [
                int(d)
                for d in state.rng.choice(
                    fed.num_devices,
                    size=min(want, fed.num_devices),
                    replace=False,
                )
            ]
        rates, levels = self.round_arms(state, len(cohort))
        return RoundPlan(
            round_index=state.round_index,
            cohort=cohort,
            rates=rates,
            adaopt_depth=self.active_depth(state),
            compression=levels,
        )

    def client_init(self, state: RoundState, dev: int):
        """The PEFT tree a device starts its local round from."""
        return state.global_peft

    def cohort_step(self, state: RoundState, plan: RoundPlan):
        """Train the planned cohort through the execution engine."""
        key, gstep, outs = self.ctx.engine.run_cohort(
            state.key,
            state.global_step,
            plan.cohort,
            plan.rates,
            plan.start_pefts,
            self.ctx.num_classes,
            plan.adaopt_depth,
        )
        results = CohortResults(
            plan=plan,
            pefts=[o[0] for o in outs],
            metrics=[o[1] for o in outs],
            importances=[o[2] for o in outs],
            accuracies=[o[3] for o in outs],
        )
        return replace(state, key=key, global_step=gstep), results

    def compress_uplink(self, state: RoundState, results: CohortResults):
        """Compress each device's PEFT *delta* for the uplink.

        Runs between ``cohort_step`` and ``aggregate``.  With no
        ``ctx.compression`` (or every per-device level ``"none"``) this is a
        strict no-op — ``results`` is untouched, ``uplink_pefts`` stays
        ``None``, and downstream merge/billing follow the pre-compression
        bit-exact path.  Otherwise it fills ``results.uplink_pefts`` with
        the server-side reconstructions (start tree + lossy delta),
        ``results.uplink_ratio`` with per-device compressed/fp32 wire
        factors, and threads per-device :class:`ErrorFeedback` residuals
        through ``state.ef_residual`` (EF runs client-side at training
        time, so it is correct even for updates the scheduler later carries
        or drops)."""
        comp = getattr(self.ctx, "compression", None)
        if comp is None:
            return state, results
        plan = results.plan
        levels = plan.compression or [comp.kind] * len(plan.cohort)
        plan.compression = levels
        if all(lv == "none" for lv in levels):
            return state, results
        starts = plan.start_pefts
        if starts is None:
            starts = [self.client_init(state, dev) for dev in plan.cohort]
        ef_residual = dict(state.ef_residual)
        uplinks, ratios = [], []
        f32 = jax.numpy.float32
        for i, dev in enumerate(plan.cohort):
            kind = levels[i]
            if kind == "none":
                uplinks.append(results.pefts[i])
                ratios.append(1.0)
                continue
            start = starts[i]
            delta = jax.tree.map(
                lambda a, b: a.astype(f32) - b.astype(f32),
                results.pefts[i],
                start,
            )
            if comp.error_feedback:
                residual = ef_residual.get(dev)
                if residual is None or not _trees_congruent(residual, delta):
                    residual = compression_lib.ErrorFeedback.init(delta)
                sent, new_res = compression_lib.ef_step(
                    delta,
                    residual,
                    kind=kind,
                    fraction=comp.topk_fraction,
                    decay=comp.ef_decay,
                )
                ef_residual[dev] = new_res
            else:
                sent = compression_lib.compress_decompress(
                    delta, kind=kind, fraction=comp.topk_fraction
                )
            uplinks.append(
                jax.tree.map(
                    lambda s_, b: (b.astype(f32) + s_).astype(b.dtype),
                    sent,
                    start,
                )
            )
            ratios.append(
                compression_lib.uplink_ratio(
                    delta,
                    compression_lib.CompressionConfig(
                        kind=kind, topk_fraction=comp.topk_fraction
                    ),
                )
            )
        results.uplink_pefts = uplinks
        results.uplink_ratio = np.asarray(ratios, dtype=np.float64)
        return replace(state, ef_residual=ef_residual), results

    def aggregate(self, state: RoundState, results: CohortResults) -> RoundState:
        """Compute share masks, persist device models, merge the global.

        Reuses ``results.masks`` when the scheduler already computed them at
        dispatch time (deadline/async policies need per-device upload
        fractions before the round closes)."""
        masks = results.masks if results.masks is not None else self.compute_masks(
            state, results
        )
        results.masks = masks
        device_peft = dict(state.device_peft)
        last_mask = dict(state.last_mask)
        for i, dev in enumerate(results.plan.cohort):
            device_peft[dev] = results.pefts[i]
            last_mask[dev] = masks[i]
        global_peft = self.merge(state, results)
        return replace(
            state, device_peft=device_peft, last_mask=last_mask, global_peft=global_peft
        )

    def round_cost(self, state: RoundState, results: CohortResults):
        """System-model cost accounting for a trained cohort.

        Draws one bandwidth sample per cohort member (in cohort order, from
        ``state.rng``) and runs the vectorized ``SystemModel`` round cost;
        fills ``results.cost`` and returns ``(cost, active_fracs)``.  Shared
        by the synchronous :meth:`report` and the virtual-clock scheduler's
        dispatch so the two accountings can never drift apart."""
        ctx, fed = self.ctx, self.ctx.fed_cfg
        cohort = results.plan.cohort
        n = len(cohort)
        bandwidths = np.array([sample_bandwidth(state.rng) for _ in cohort])
        # one batched host pull — sequential-mode metrics are device arrays,
        # and a per-device float() loop would sync once per member
        active = np.asarray(
            jax.device_get([m["active_layers"] for m in results.metrics]),
            dtype=np.float64,
        )
        active_fracs = (active / ctx.cfg.num_layers).tolist()
        if results.masks is None:
            # a custom aggregate() may not fill masks in; cost accounting
            # then assumes every layer is shared
            results.masks = self.compute_masks(state, results)
        cost = ctx.system.cohort_round_cost(
            devices=[ctx.device_profile[dev] for dev in cohort],
            bandwidth_mbps=bandwidths,
            batch=fed.batch_size,
            seq=ctx.task.seq_len,
            local_steps=fed.local_steps,
            peft=True,
            active_fraction=(
                np.asarray(active_fracs) if self.stld else np.ones(n)
            ),
            share_fraction=results.masks.mean(axis=1),
            uplink_ratio=(
                1.0
                if results.uplink_ratio is None
                else np.asarray(results.uplink_ratio, dtype=np.float64)
            ),
        )
        results.cost = cost
        return cost, active_fracs

    def report(self, state: RoundState, results: CohortResults):
        """System-model accounting + feedback; returns (state, history row)."""
        plan = results.plan
        cohort = plan.cohort
        n = len(cohort)
        cost, active_fracs = self.round_cost(state, results)
        round_times = cost.total_time_s
        cum_time = state.cum_time + float(round_times.max())  # synchronous round
        mean_acc = float(np.mean(results.accuracies))
        self.feedback(state, results, round_times)
        prev_acc = dict(state.prev_acc)
        for i, dev in enumerate(cohort):
            prev_acc[dev] = results.accuracies[i]
        row = {
            "time": cum_time,
            "acc": mean_acc,
            "loss": float(
                np.mean(
                    np.asarray(
                        jax.device_get([m["loss"] for m in results.metrics]),
                        dtype=np.float64,
                    )
                )
            ),
            "rate": float(np.mean(plan.rates)),
            "active": float(np.mean(active_fracs)),
            "traffic": float(cost.traffic_mb.sum()),
            "energy": float(cost.energy_j.sum()),
            "memory": float(cost.memory_gb.max()),
            "arrivals": n,  # synchronous barrier: everyone arrives
        }
        return replace(state, cum_time=cum_time, prev_acc=prev_acc), row

    # ------------------------------------------------------- policy methods
    def round_rates(self, state: RoundState, n: int) -> List[float]:
        if state.configurator is not None:
            return state.configurator.next_round(n)
        if self.stld:
            return [self.fixed_rate] * n
        return [0.0] * n

    def round_arms(self, state: RoundState, n: int):
        """Per-device (dropout rates, compression levels) for the round.

        With a joint configurator both axes come from one bandit draw;
        otherwise the rates come from :meth:`round_rates` (identical RNG
        stream to the pre-compression loop) and the levels stay ``None``
        (``compress_uplink`` fills in the fixed configured level)."""
        cfgor = state.configurator
        if cfgor is not None and getattr(cfgor, "joint", False):
            return cfgor.next_round_joint(n)
        return self.round_rates(state, n), None

    def active_depth(self, state: RoundState) -> int:
        return self.ctx.cfg.num_layers

    def compute_masks(self, state: RoundState, results: CohortResults):
        n = len(results.plan.cohort)
        return np.ones((n, self.ctx.cfg.num_layers), dtype=bool)

    def _merge_trees(self, results: CohortResults) -> list:
        """What the server aggregates: the (dequantized, densified) uplink
        reconstructions when compression ran, the raw device trees when it
        didn't."""
        return results.pefts if results.uplink_pefts is None else results.uplink_pefts

    def merge(self, state: RoundState, results: CohortResults):
        trees = self._merge_trees(results)
        if results.weights is not None:
            return self.ctx.engine.weighted_fedavg(trees, results.weights)
        return self.ctx.engine.fedavg(trees)

    def feedback(self, state: RoundState, results: CohortResults, round_times):
        """Hook for online controllers (bandit reward updates)."""
