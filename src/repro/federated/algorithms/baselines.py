"""Federated-PEFT baselines from the paper's evaluation (§6).

    FedLoRA / FedAdapter — vanilla federated PEFT (FedAvg, full depth)
    FedHetLoRA           — rank-heterogeneous LoRA matched to device tiers
    FedAdaOPT            — progressive-depth adapter training
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core import peft as peft_lib
from repro.federated import server as server_lib
from repro.federated.algorithms.base import FederatedAlgorithm, register
from repro.federated.state import CohortResults, RoundState


@register("fedlora")
class FedLoRA(FederatedAlgorithm):
    """Vanilla federated LoRA: FedAvg over homogeneous client trees."""


@register("fedadapter")
class FedAdapter(FederatedAlgorithm):
    """Vanilla federated adapters (same loop; the PEFT kind comes from
    ``peft_cfg.method``)."""


@register("fedhetlora")
class FedHetLoRA(FederatedAlgorithm):
    """Rank-heterogeneous LoRA: each device trains at the rank its hardware
    tier affords; the server zero-pads to the max rank and aggregates with
    sparsity weighting.  Differently-shaped client trees cannot share one
    vmap axis, so the cohort runs sequentially."""

    requires_sequential = True
    hetlora_ranks = (4, 8, 16)

    def __init__(self, *, ranks: Optional[Sequence[int]] = None):
        super().__init__()
        if ranks is not None:
            self.hetlora_ranks = tuple(ranks)

    def bind(self, ctx):
        super().bind(ctx)
        # per-device LoRA rank from device capability tier
        tiers = {"tx2": 0, "nx": 1, "agx": 2}
        self.device_rank = [
            self.hetlora_ranks[tiers[p]] for p in ctx.device_profile
        ]
        self.max_rank = max(self.hetlora_ranks)
        # global tree holds the max rank
        pc = ctx.peft_cfg.__class__(
            **{**ctx.peft_cfg.__dict__, "lora_rank": self.max_rank}
        )
        return peft_lib.init_peft(ctx.peft_key, ctx.cfg, pc)

    def client_init(self, state: RoundState, dev: int):
        return server_lib.truncate_lora_rank(state.global_peft, self.device_rank[dev])

    def merge(self, state: RoundState, results: CohortResults):
        client_ranks = [self.device_rank[dev] for dev in results.plan.cohort]
        # staleness weights (async/carry scheduling) multiply the rank shares
        return server_lib.hetlora_aggregate(
            self._merge_trees(results), client_ranks, self.max_rank,
            extra_weights=results.weights,
        )


@register("fedadaopt")
class FedAdaOPT(FederatedAlgorithm):
    """Progressive-depth adapters: start shallow, grow the trainable depth
    by two layers every ``adaopt_grow_every`` rounds; updates beyond the
    active depth are discarded before evaluation."""

    adaopt_grow_every = 5

    def __init__(self, *, grow_every: Optional[int] = None):
        super().__init__()
        if grow_every is not None:
            self.adaopt_grow_every = grow_every

    def active_depth(self, state: RoundState) -> int:
        return min(
            self.ctx.cfg.num_layers,
            2 + (state.round_index // self.adaopt_grow_every) * 2,
        )
