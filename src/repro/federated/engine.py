"""Cohort execution engine: how one round's selected devices are trained.

The engine is algorithm-agnostic — it owns the jit'd client programs, the
per-device datasets, and the batched/sequential dispatch strategy, while the
*what* of a round (cohort choice, dropout rates, aggregation rule) lives in
:mod:`repro.federated.algorithms`.

``cohort_mode`` selects the dispatch strategy:

* ``"batched"`` — per-device batches, dropout rates, PRNG keys and
  LR-schedule offsets are stacked along a leading device axis and one jit'd
  ``cohort_round`` (``jax.vmap`` of the local round) trains the whole
  cohort; validation runs through the vmapped ``cohort_evaluate`` on padded
  val batches.  In gather-mode STLD the static active-layer count can
  differ per device, so the cohort is partitioned into same-count groups
  and each group runs as one batched call.
* ``"sequential"`` — the per-device python loop, one jit'd ``local_round``
  dispatch per device.  Required for FedHetLoRA's rank-heterogeneous PEFT
  trees, which cannot share one stacked vmap axis.

Both modes consume identical PRNG streams (one ``jax.random.split`` fan-out
per round, per-device global-step offsets in cohort order) and produce
numerically matching per-device PEFT trees, metrics, and PTLS importances —
see ``tests/test_cohort_parity.py``.

PEFT trees flow through the engine in the stacked-native layout (one leaf
per param kind, leading ``(L, ...)`` layer axis — see
:mod:`repro.models.stacking`) whenever the stack is homogeneous, so the
cohort stack/unstack helpers and every client dispatch handle O(k) leaves
instead of O(L·k); the per-layer list layout (hetlora, legacy callers)
keeps working through the same code paths.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stld as stld_lib
from repro.federated import server as server_lib
from repro.federated.client import make_client_fns
from repro.models import stacking
from repro.models.registry import default_stack_mode
from repro.optim import adamw_init


class CohortEngine:
    """Executes cohorts of local rounds; owns jit caches and device data."""

    def __init__(
        self,
        cfg,
        peft_cfg,
        stld_cfg,
        fed_cfg,
        train_cfg,
        task,
        devices,
        base_params,
        *,
        cohort_mode: str,
        stld_enabled: bool,
    ):
        self.cfg = cfg
        self.base_params = base_params
        self.peft_cfg = peft_cfg
        self.stld_cfg = stld_cfg
        self.fed_cfg = fed_cfg
        self.train_cfg = train_cfg
        self.task = task
        self.devices = devices
        self.cohort_mode = cohort_mode
        self.stld_enabled = stld_enabled

        self.stack_mode = default_stack_mode(cfg)
        self.client = make_client_fns(
            cfg, peft_cfg, stld_cfg, train_cfg, stack_mode=self.stack_mode
        )
        self.local_round, self.evaluate = self.client.local_round, self.client.evaluate
        # server aggregation is pure tree math: jit it so a round's
        # aggregation is one dispatch instead of hundreds of tiny ops
        self.fedavg = jax.jit(server_lib.fedavg)
        self.weighted_fedavg = jax.jit(server_lib.weighted_fedavg)
        self.ptls_aggregate = jax.jit(server_lib.ptls_aggregate)
        # fixed val pad size so the jit'd cohort_evaluate signature is stable
        self._val_pad = max(len(d.val_batch()["labels"]) for d in devices)
        self._val_cache: Dict[int, dict] = {}
        self._all_val_stack = None  # cohort-wide stacked val tensors (final_accuracy)
        self._stack_cache: Dict[int, object] = {}
        self._unstack_cache: Dict[int, object] = {}
        self._truncate_cache: Dict[tuple, object] = {}
        # FedHetLoRA: per-device LoRA rank + per-rank client programs
        self.device_rank: Optional[List[int]] = None
        self._het_fns: Dict[int, object] = {}

    def enable_hetlora(self, device_rank: List[int]):
        """Build per-rank client programs for rank-heterogeneous cohorts."""
        self.device_rank = list(device_rank)
        for r in set(self.device_rank):
            pc = self.peft_cfg.__class__(
                **{**self.peft_cfg.__dict__, "lora_rank": r}
            )
            self._het_fns[r] = make_client_fns(
                self.cfg, pc, self.stld_cfg, self.train_cfg, stack_mode=self.stack_mode
            )

    # ------------------------------------------------------------- execution
    def run_cohort(self, key, global_step, cohort, rates, start_pefts, num_classes, adaopt_depth):
        """Train one round's cohort; returns ``(new_key, new_global_step,
        outs)`` where ``outs`` is a list (len N) of per-device
        ``(peft, metrics, importance, accuracy)`` tuples.  Both modes draw
        from identical PRNG streams: one split fan-out for the per-device
        keys, per-device global-step offsets in cohort order."""
        fed = self.fed_cfg
        n = len(cohort)
        key, *keys = jax.random.split(key, n + 1)
        gsteps = [global_step + i * fed.local_steps for i in range(n)]
        new_gstep = global_step + n * fed.local_steps

        if self.cohort_mode == "batched":
            outs = self._run_cohort_batched(
                cohort, rates, start_pefts, keys, gsteps, num_classes, adaopt_depth
            )
        else:
            outs = [
                self._run_device(
                    cohort[i], rates[i], start_pefts[i], keys[i], gsteps[i],
                    num_classes, adaopt_depth,
                )
                for i in range(n)
            ]
        return key, new_gstep, outs

    def _adaopt_truncate(self, peft_i, start_peft, adaopt_depth: int, axis: int = 0):
        """Progressive depth (FedAdaOPT): layers beyond the active depth keep
        their incoming values — their adapter updates are discarded BEFORE
        evaluation, so reported accuracy measures the retained model.

        Stacked trees use one jit'd ``jnp.where`` over the layer axis
        (``axis`` = 1 for cohort-stacked ``(N, L, ...)`` leaves); exact
        copies, bit-identical to the per-layer list selection."""
        if isinstance(peft_i, (list, tuple)):
            return [
                peft_i[l] if l < adaopt_depth else start_peft[l]
                for l in range(self.cfg.num_layers)
            ]
        fn = self._truncate_cache.get((adaopt_depth, axis))
        if fn is None:
            keep = np.arange(self.cfg.num_layers) < adaopt_depth
            fn = jax.jit(partial(stacking.select_layers, keep, axis=axis))
            self._truncate_cache[(adaopt_depth, axis)] = fn
        return fn(peft_i, start_peft)

    def _stacked_train_batches(self, dev: int):
        fed = self.fed_cfg
        batches = list(self.devices[dev].train_batches(fed.batch_size, fed.local_steps))
        return {
            k: np.stack([b[k] for b in batches]) for k in ("tokens", "targets", "mask")
        }

    def _padded_val_batch(self, dev: int):
        """Val batch padded to the cohort-wide size with a validity mask.
        Val splits are static, so the padded batch is built once per device."""
        cached = self._val_cache.get(dev)
        if cached is None:
            val = self.devices[dev].val_batch()
            b = len(val["labels"])
            pad = self._val_pad - b
            valid = np.zeros((self._val_pad,), dtype=np.float32)
            valid[:b] = 1.0
            cached = {
                "tokens": np.pad(val["tokens"], ((0, pad), (0, 0))),
                "labels": np.pad(val["labels"], (0, pad)),
                "valid": valid,
            }
            self._val_cache[dev] = cached
        return cached

    def _static_active_counts(self, rates) -> List[Optional[int]]:
        """Gather-mode static active-layer count per device (None in cond
        mode).  Static counts partition the batched cohort into groups."""
        if self.stld_cfg.mode == "gather" and self.stld_enabled:
            return [
                stld_lib.static_active_count(
                    rate,
                    self.cfg.num_layers,
                    self.stld_cfg.gather_bucket,
                    self.stld_cfg.min_active_layers,
                )
                for rate in rates
            ]
        return [None] * len(rates)

    def _run_cohort_batched(
        self, cohort, rates, start_pefts, keys, gsteps, num_classes, adaopt_depth
    ):
        """One (or few, in gather mode) jit'd calls train the whole cohort."""
        n = len(cohort)
        adaopt = adaopt_depth < self.cfg.num_layers
        batch_list = [self._stacked_train_batches(dev) for dev in cohort]
        val_list = [self._padded_val_batch(dev) for dev in cohort]
        num_active = self._static_active_counts(rates)

        outs: List[Optional[tuple]] = [None] * n
        for na in dict.fromkeys(num_active):
            pos = [i for i in range(n) if num_active[i] == na]
            peft_stack = self._stack_trees([start_pefts[i] for i in pos])
            batch_stack = {
                k: jnp.asarray(np.stack([batch_list[i][k] for i in pos]))
                for k in ("tokens", "targets", "mask")
            }
            rate_arr = jnp.asarray(np.asarray(rates, dtype=np.float32)[pos])
            key_arr = jnp.stack([keys[i] for i in pos])
            gstep_arr = jnp.asarray([gsteps[i] for i in pos], dtype=jnp.int32)
            val_args = (
                jnp.asarray(np.stack([val_list[i]["tokens"] for i in pos])),
                jnp.asarray(np.stack([val_list[i]["labels"] for i in pos])),
                jnp.asarray(np.stack([val_list[i]["valid"] for i in pos])),
            )
            if adaopt:
                # progressive depth discards deep-layer updates before eval,
                # so train and eval cannot be fused: train, truncate the
                # stacked tree per layer, then evaluate the retained model
                peft_out, metrics, importances = self.client.cohort_round(
                    self.base_params, peft_stack, batch_stack,
                    rate_arr, key_arr, gstep_arr, num_active=na,
                )
                peft_out = self._adaopt_truncate(
                    peft_out, peft_stack, adaopt_depth,
                    axis=0 if isinstance(peft_out, (list, tuple)) else 1,
                )
                accs = self.client.cohort_evaluate(
                    self.base_params, peft_out, *val_args, num_classes
                )
            else:
                peft_out, metrics, importances, accs = self.client.cohort_round_eval(
                    self.base_params,
                    peft_stack,
                    batch_stack,
                    rate_arr,
                    key_arr,
                    gstep_arr,
                    *val_args,
                    num_classes,
                    num_active=na,
                )
            # one jit'd unstack + one host pull: per-leaf x[j] slicing and
            # per-device float() syncs would cost hundreds of tiny dispatches
            peft_list = self._unstack_tree(peft_out, len(pos))
            metrics_np, imps_np, accs_np = jax.device_get((metrics, importances, accs))
            accs_list = np.asarray(accs_np).tolist()
            for j, i in enumerate(pos):
                dev_metrics = {k: v[j] for k, v in metrics_np.items()}
                outs[i] = (peft_list[j], dev_metrics, imps_np[j], accs_list[j])
        return outs

    def _stack_trees(self, trees):
        """Stack a list of identically-shaped pytrees along a new leading
        axis in ONE jit'd dispatch (cached per cohort-group size)."""
        n = len(trees)
        fn = self._stack_cache.get(n)
        if fn is None:
            fn = jax.jit(lambda *ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts))
            self._stack_cache[n] = fn
        return fn(*trees)

    def _unstack_tree(self, tree, n: int):
        """Split a leading-(n,) stacked pytree into n pytrees in ONE jit'd
        dispatch (cached per cohort-group size)."""
        fn = self._unstack_cache.get(n)
        if fn is None:
            fn = jax.jit(lambda t: tuple(jax.tree.map(lambda x: x[j], t) for j in range(n)))
            self._unstack_cache[n] = fn
        return fn(tree)

    def _run_device(
        self, dev: int, rate: float, start_peft, key, gstep: int, num_classes, adaopt_depth
    ):
        if self.device_rank is not None:
            fns = self._het_fns[self.device_rank[dev]]
            local_round, evaluate = fns.local_round, fns.evaluate
        else:
            local_round, evaluate = self.local_round, self.evaluate

        stacked = {
            k: jnp.asarray(v) for k, v in self._stacked_train_batches(dev).items()
        }
        opt_state = adamw_init(start_peft)
        num_active = self._static_active_counts([rate])[0]
        peft_i, _, metrics, importance = local_round(
            self.base_params,
            start_peft,
            opt_state,
            stacked,
            jnp.asarray(rate, dtype=jnp.float32),
            key,
            jnp.asarray(gstep, dtype=jnp.int32),
            num_active=num_active,
        )
        if adaopt_depth < self.cfg.num_layers:
            peft_i = self._adaopt_truncate(peft_i, start_peft, adaopt_depth)
        # one host pull for the round's scalars; downstream per-field float()
        # reads then touch numpy, not device buffers
        metrics, importance = jax.device_get((metrics, importance))

        val = self.devices[dev].val_batch()
        acc = float(
            evaluate(
                self.base_params,
                peft_i,
                jnp.asarray(val["tokens"]),
                jnp.asarray(val["labels"]),
                num_classes,
            )
        )
        return peft_i, metrics, importance, acc

    # ------------------------------------------------------------ evaluation
    def final_accuracy(self, global_peft, device_peft, num_classes) -> float:
        """Paper protocol: mean accuracy across ALL devices' local test sets,
        each device using its personalized model (global for non-participants)."""
        hetlora = self.device_rank is not None
        if self.cohort_mode == "batched" and not hetlora:
            devs = range(self.fed_cfg.num_devices)
            peft_stack = self._stack_trees(
                [device_peft.get(dev, global_peft) for dev in devs]
            )
            if self._all_val_stack is None:
                # val splits are static: build the cohort-wide stacked val
                # tensors once instead of re-stacking them on every call
                vals = [self._padded_val_batch(dev) for dev in devs]
                self._all_val_stack = tuple(
                    jnp.asarray(np.stack([v[k] for v in vals]))
                    for k in ("tokens", "labels", "valid")
                )
            accs = self.client.cohort_evaluate(
                self.base_params, peft_stack, *self._all_val_stack, num_classes
            )
            return float(np.mean(np.asarray(accs)))
        accs = []
        for dev in range(self.fed_cfg.num_devices):
            peft_d = device_peft.get(dev, global_peft)
            if hetlora and dev not in device_peft:
                peft_d = server_lib.truncate_lora_rank(global_peft, self.device_rank[dev])
            evaluate = (
                self._het_fns[self.device_rank[dev]].evaluate if hetlora else self.evaluate
            )
            val = self.devices[dev].val_batch()
            accs.append(
                float(
                    evaluate(
                        self.base_params,
                        peft_d,
                        jnp.asarray(val["tokens"]),
                        jnp.asarray(val["labels"]),
                        num_classes,
                    )
                )
            )
        return float(np.mean(accs))
