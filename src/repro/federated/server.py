"""Server-side aggregation strategies.

* ``fedavg``            — plain mean of client PEFT trees (FedLoRA/FedAdapter
                          and the DropPEFT-b3 ablation).
* ``ptls_aggregate``    — heterogeneous layer aggregation (paper Fig. 8):
                          per layer, average only the devices that shared it.
* ``hetlora_aggregate`` — FedHetLoRA baseline: rank-heterogeneous LoRA
                          updates zero-padded to the max rank then
                          sparsity-weighted averaged.
* ``cohort_shared_masks`` — batched PTLS: per-device share masks from a
                          stacked (N, L) importance matrix in one jit'd call.
"""
from __future__ import annotations

from functools import partial
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ptls


@partial(jax.jit, static_argnames=("k",))
def cohort_shared_masks(importances, k: int):
    """(N, L) importances -> (N, L) bool share masks (Eq. 6 / Fig. 8).

    Row n is ``ptls.shared_layer_mask(importances[n], k)``: the k
    lowest-importance layers each device uploads.  vmapped so the whole
    cohort's mask computation is a single dispatch when the batched engine
    hands back stacked importances.
    """
    return jax.vmap(lambda imp: ptls.shared_layer_mask(imp, k))(importances)


def fedavg(client_trees: Sequence) -> object:
    """Mean over clients of identical pytrees."""
    return jax.tree.map(lambda *xs: sum(xs) / len(xs), *client_trees)


def ptls_aggregate(client_peft: Sequence[List], masks: np.ndarray, global_peft: List) -> List:
    """client_peft: per-client per-layer PEFT lists; masks: (N, L) bool."""
    num_layers = len(global_peft)
    stacked = [
        jax.tree.map(lambda *xs: jnp.stack(xs), *[c[l] for c in client_peft])
        for l in range(num_layers)
    ]
    return ptls.masked_layer_mean(stacked, jnp.asarray(masks), global_peft)


def _pad_lora(lora: dict, rank: int) -> dict:
    a, b = lora["a"], lora["b"]
    pa = jnp.pad(a, ((0, 0), (0, rank - a.shape[1])))
    pb = jnp.pad(b, ((0, rank - b.shape[0]), (0, 0)))
    return {"a": pa, "b": pb}


def hetlora_aggregate(client_peft: Sequence[List], ranks: Sequence[int], max_rank: int) -> List:
    """FedHetLoRA: zero-pad heterogeneous-rank LoRA factors to ``max_rank``;
    weight each client by its rank share (sparsity-weighted aggregation)."""
    weights = np.asarray(ranks, dtype=np.float64)
    weights = weights / weights.sum()
    num_layers = len(client_peft[0])
    out = []
    for l in range(num_layers):
        padded = []
        for c, w in zip(client_peft, weights):
            layer = c[l]
            padded.append(
                jax.tree.map(
                    lambda x: x,
                    {
                        grp: {t: _pad_lora(lora, max_rank) for t, lora in sub.items()}
                        for grp, sub in layer.items()
                    },
                )
            )
        agg = jax.tree.map(
            lambda *xs: sum(w * x for w, x in zip(weights, xs)), *padded
        )
        out.append(agg)
    return out


def truncate_lora_rank(peft_layers: List, rank: int) -> List:
    """Project a max-rank global LoRA tree down to a client's local rank."""
    def trunc(lora):
        return {"a": lora["a"][:, :rank], "b": lora["b"][:rank, :]}

    return [
        {grp: {t: trunc(lora) for t, lora in sub.items()} for grp, sub in layer.items()}
        for layer in peft_layers
    ]
