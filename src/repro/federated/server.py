"""Server-side aggregation strategies.

* ``fedavg``            — plain mean of client PEFT trees (FedLoRA/FedAdapter
                          and the DropPEFT-b3 ablation).
* ``ptls_aggregate``    — heterogeneous layer aggregation (paper Fig. 8):
                          per layer, average only the devices that shared it.
* ``hetlora_aggregate`` — FedHetLoRA baseline: rank-heterogeneous LoRA
                          updates zero-padded to the max rank then
                          sparsity-weighted averaged.
* ``cohort_shared_masks`` — batched PTLS: per-device share masks from a
                          stacked (N, L) importance matrix in one jit'd call.
* ``select_layers``     — per-layer global/local mix for PTLS client init
                          on stacked trees (one jit'd ``jnp.where``).

Every aggregator accepts both layer layouts (:mod:`repro.models.stacking`):
the stacked-native layout collapses the per-layer python loops into a few
vectorized ``(N, L, ...)`` reductions; the list layout keeps the original
per-layer code path (exercised by the frozen legacy-simulator parity
baseline) and produces bit-identical results.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ptls
from repro.models import stacking


@partial(jax.jit, static_argnames=("k",))
def cohort_shared_masks(importances, k: int):
    """(N, L) importances -> (N, L) bool share masks (Eq. 6 / Fig. 8).

    Row n is ``ptls.shared_layer_mask(importances[n], k)``: the k
    lowest-importance layers each device uploads.  vmapped so the whole
    cohort's mask computation is a single dispatch when the batched engine
    hands back stacked importances.
    """
    return jax.vmap(lambda imp: ptls.shared_layer_mask(imp, k))(importances)


def screen_finite(tree, fallback=None):
    """Last-line non-finite screen on an aggregated tree (traced).

    The scheduler already rejects non-finite client updates host-side
    before they reach aggregation; this guard is the defense-in-depth
    layer *inside* the traced aggregation programs, so even an update
    that bypasses host screening (a custom algorithm, a direct
    ``server_lib`` caller) cannot poison the global PEFT.  Non-finite
    output entries are replaced by ``fallback`` (matching tree) or zero.

    Bit-transparency: ``jnp.where`` lowers to ``select_n``, which returns
    the selected operand *exactly*, and on an all-finite tree every lane
    selects the aggregated value — so healthy runs are bit-identical with
    or without the guard (the schedule-parity suite pins this).  The
    ``is_finite`` primitive this traces into the jaxpr is what the
    ``repro.analysis`` finite-guard contract asserts is present.
    """
    if fallback is None:
        return jax.tree.map(
            lambda x: jnp.where(jnp.isfinite(x), x, jnp.zeros_like(x)), tree
        )
    return jax.tree.map(
        lambda x, f: jnp.where(jnp.isfinite(x), x, f), tree, fallback
    )


def fedavg(client_trees: Sequence) -> object:
    """Mean over clients of identical pytrees (layout-agnostic)."""
    return screen_finite(jax.tree.map(lambda *xs: sum(xs) / len(xs), *client_trees))


def staleness_weights(staleness, alpha: float) -> np.ndarray:
    """FedBuff-style staleness discount: w_i ∝ 1/(1+s_i)^alpha, normalized.

    ``staleness`` is the per-update server-version lag (0 = trained on the
    current global model).  ``alpha=0`` is uniform weighting — callers
    should pass ``weights=None`` in that case so aggregation stays on the
    bit-exact unweighted path.
    """
    s = np.asarray(staleness, dtype=np.float64)
    w = 1.0 / np.power(1.0 + s, float(alpha))
    return w / w.sum()


def weighted_fedavg(client_trees: Sequence, weights) -> object:
    """Staleness-weighted mean over clients of identical pytrees.

    ``weights`` must already be normalized (sum to 1); layout-agnostic like
    :func:`fedavg` and jit-safe (weights may be traced).
    """
    w = jnp.asarray(weights, dtype=jnp.float32).ravel()
    return screen_finite(
        jax.tree.map(
            lambda *xs: sum(w[i] * x for i, x in enumerate(xs)), *client_trees
        )
    )


@jax.jit
def select_layers(mask, global_tree, own_tree):
    """Stacked-tree PTLS client init: layer ``l`` from ``global_tree`` where
    ``mask[l]`` (shared -> refreshed from the server) else from
    ``own_tree`` (personalized -> kept local).  Exact per-layer copies, so
    it is bit-identical to the per-layer python selection on lists."""
    return stacking.select_layers(mask, global_tree, own_tree)


def ptls_aggregate(client_peft, masks, global_peft, weights=None):
    """Heterogeneous PTLS aggregation (paper Fig. 8).

    ``client_peft``: per-client PEFT trees (sequence), or a single stacked
    cohort tree whose leaves already carry a leading ``(N, ...)`` device
    axis.  ``masks``: (N, L) bool.  ``global_peft`` sets the output layout.
    ``weights`` (optional, (N,)) switches to the staleness-weighted masked
    mean used by the async virtual-clock scheduler; ``None`` keeps the
    bit-exact unweighted path.
    """
    if isinstance(global_peft, (list, tuple)):
        # list layout: per-layer stack over clients, then per-layer masked mean
        num_layers = len(global_peft)
        stacked = [
            jax.tree.map(lambda *xs: jnp.stack(xs), *[c[l] for c in client_peft])
            for l in range(num_layers)
        ]
        return screen_finite(
            ptls.masked_layer_mean(stacked, jnp.asarray(masks), global_peft, weights),
            fallback=global_peft,
        )
    if isinstance(client_peft, (list, tuple)):
        client_peft = jax.tree.map(lambda *xs: jnp.stack(xs), *client_peft)
    return screen_finite(
        ptls.masked_layer_mean(client_peft, jnp.asarray(masks), global_peft, weights),
        fallback=global_peft,
    )


def _pad_lora(lora: dict, rank: int) -> dict:
    """Zero-pad LoRA factors to ``rank`` along the rank axis; works for
    per-layer ``(d, r)``/``(r, d)`` and stacked ``(L, d, r)``/``(L, r, d)``
    leaves alike (axis-relative pad spec)."""
    a, b = lora["a"], lora["b"]
    pad_a = [(0, 0)] * a.ndim
    pad_a[-1] = (0, rank - a.shape[-1])
    pad_b = [(0, 0)] * b.ndim
    pad_b[-2] = (0, rank - b.shape[-2])
    return {"a": jnp.pad(a, pad_a), "b": jnp.pad(b, pad_b)}


def _pad_layer(layer: dict, rank: int) -> dict:
    return {
        grp: {t: _pad_lora(lora, rank) for t, lora in sub.items()}
        for grp, sub in layer.items()
    }


@jax.jit
def _weighted_tree_mean(weights, *trees):
    """Sparsity-weighted mean over identically-shaped client trees, one
    jit'd dispatch (the padded hetlora aggregation body)."""
    return screen_finite(
        jax.tree.map(
            lambda *xs: sum(w * x for w, x in zip(weights, xs)), *trees
        )
    )


def hetlora_aggregate(
    client_peft: Sequence, ranks: Sequence[int], max_rank: int, extra_weights=None
):
    """FedHetLoRA: zero-pad heterogeneous-rank LoRA factors to ``max_rank``;
    weight each client by its rank share (sparsity-weighted aggregation).

    ``extra_weights`` (optional, (N,)) multiplies the rank shares — the
    scheduler passes staleness weights through it; the product is
    renormalized.  ``None`` keeps the bit-exact rank-only weighting.

    Accepts per-client trees in either layout; the padded aggregation body
    runs as one jit'd call per layout/shape signature.
    """
    weights = np.asarray(ranks, dtype=np.float64)
    weights = weights / weights.sum()
    if extra_weights is not None:
        weights = weights * np.asarray(extra_weights, dtype=np.float64)
        weights = weights / weights.sum()
    weights = tuple(float(w) for w in weights)
    if not isinstance(client_peft[0], (list, tuple)):
        padded = [_pad_layer(c, max_rank) for c in client_peft]
        return _weighted_tree_mean(weights, *padded)
    num_layers = len(client_peft[0])
    out = []
    for l in range(num_layers):
        padded = [_pad_layer(c[l], max_rank) for c in client_peft]
        out.append(_weighted_tree_mean(weights, *padded))
    return out


def truncate_lora_rank(peft_layers, rank: int):
    """Project a max-rank global LoRA tree down to a client's local rank
    (axis-relative slices: valid for both layer layouts)."""
    def trunc(lora):
        return {"a": lora["a"][..., :rank], "b": lora["b"][..., :rank, :]}

    def trunc_layer(layer):
        return {
            grp: {t: trunc(lora) for t, lora in sub.items()}
            for grp, sub in layer.items()
        }

    if isinstance(peft_layers, (list, tuple)):
        return [trunc_layer(layer) for layer in peft_layers]
    return trunc_layer(peft_layers)
