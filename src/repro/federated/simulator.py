"""End-to-end federated fine-tuning simulator.

One object runs any of the paper's methods and ablations over the synthetic
classification task:

    FedLoRA / FedAdapter          — vanilla federated PEFT baselines
    FedHetLoRA                    — rank-heterogeneous LoRA baseline
    FedAdaOPT                     — progressive-depth adapter baseline
    DropPEFT (LoRA | Adapter)     — STLD + bandit configurator + PTLS
    DropPEFT-b1/b2/b3             — ablations (no STLD / fixed rate / no PTLS)

Wall-clock, memory, energy, and traffic come from the analytic SystemModel
(Jetson profiles + fluctuating bandwidth), scaled by each round's *measured*
active-layer fraction — the semi-emulation protocol of paper §6.1.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import peft as peft_lib
from repro.core import ptls
from repro.core.configurator import OnlineConfigurator
from repro.data import DeviceDataset, dirichlet_partition, make_task
from repro.federated import server as server_lib
from repro.federated.client import make_client_fns
from repro.federated.system_model import SystemModel, sample_bandwidth, sample_device
from repro.models.registry import default_stack_mode, init_params
from repro.optim import adamw_init


@dataclass
class Strategy:
    """Which paper method/ablation to run."""

    name: str = "droppeft"
    stld: bool = True
    configurator: bool = True
    ptls: bool = True
    fixed_rate: float = 0.5          # used when configurator is off
    hetlora: bool = False            # FedHetLoRA baseline
    hetlora_ranks: tuple = (4, 8, 16)
    adaopt: bool = False             # FedAdaOPT progressive-depth baseline
    adaopt_grow_every: int = 5


METHODS: Dict[str, Strategy] = {
    "fedlora": Strategy("fedlora", stld=False, configurator=False, ptls=False),
    "fedadapter": Strategy("fedadapter", stld=False, configurator=False, ptls=False),
    "fedhetlora": Strategy(
        "fedhetlora", stld=False, configurator=False, ptls=False, hetlora=True
    ),
    "fedadaopt": Strategy(
        "fedadaopt", stld=False, configurator=False, ptls=False, adaopt=True
    ),
    "droppeft": Strategy("droppeft"),
    "droppeft_b1": Strategy("droppeft_b1", stld=False),            # w/o STLD
    "droppeft_b2": Strategy("droppeft_b2", configurator=False),    # fixed rate
    "droppeft_b3": Strategy("droppeft_b3", ptls=False),            # w/o PTLS
}


@dataclass
class SimResult:
    rounds: int
    cum_time_s: np.ndarray           # (R,)
    accuracy: np.ndarray             # (R,) mean cohort val accuracy
    loss: np.ndarray                 # (R,)
    rates: np.ndarray                # (R,) mean dropout rate used
    active_fraction: np.ndarray      # (R,) measured E[L~]/L
    traffic_mb: np.ndarray           # (R,) cohort total
    energy_j: np.ndarray             # (R,) cohort total
    memory_gb: np.ndarray            # (R,) max per-device footprint
    final_accuracy: float = 0.0

    def time_to_accuracy(self, target: float) -> Optional[float]:
        hit = np.where(self.accuracy >= target)[0]
        return float(self.cum_time_s[hit[0]]) if len(hit) else None


class FederatedSimulator:
    def __init__(
        self,
        cfg,
        peft_cfg,
        stld_cfg,
        fed_cfg,
        train_cfg,
        *,
        strategy: Strategy | str = "droppeft",
        task=None,
        cost_cfg=None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.peft_cfg = peft_cfg
        self.stld_cfg = stld_cfg
        self.fed_cfg = fed_cfg
        self.train_cfg = train_cfg
        self.strategy = METHODS[strategy] if isinstance(strategy, str) else strategy
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)

        self.task = task or make_task(vocab_size=cfg.vocab_size, seed=seed)
        parts = dirichlet_partition(
            self.task.labels, fed_cfg.num_devices, fed_cfg.dirichlet_alpha, seed=seed
        )
        self.devices = [
            DeviceDataset(self.task, idx, seed=seed + i) for i, idx in enumerate(parts)
        ]
        self.device_profile = [sample_device(self.rng) for _ in range(fed_cfg.num_devices)]

        self.key, k1, k2 = jax.random.split(self.key, 3)
        self.base_params = init_params(k1, cfg)
        self.global_peft = peft_lib.init_peft(k2, cfg, peft_cfg)
        self.device_peft: Dict[int, list] = {}
        stack_mode = default_stack_mode(cfg)
        self.local_round, self.evaluate = make_client_fns(
            cfg, peft_cfg, stld_cfg, train_cfg, stack_mode=stack_mode
        )
        self.system = SystemModel(cost_cfg or cfg, peft_cfg)
        self.configurator = (
            OnlineConfigurator(
                rate_grid=fed_cfg.rate_grid,
                num_candidates=fed_cfg.num_candidates,
                explore_rate=fed_cfg.explore_rate,
                explore_interval=fed_cfg.explore_interval,
                window_size=fed_cfg.window_size,
                seed=seed,
            )
            if self.strategy.configurator and self.strategy.stld
            else None
        )
        self._prev_acc: Dict[int, float] = {}
        self._global_step = 0
        if self.strategy.hetlora:
            # per-device LoRA rank from device capability tier
            tiers = {"tx2": 0, "nx": 1, "agx": 2}
            self.device_rank = [
                self.strategy.hetlora_ranks[tiers[p]] for p in self.device_profile
            ]
            self.max_rank = max(self.strategy.hetlora_ranks)
            # global tree holds the max rank
            self.global_peft = peft_lib.init_peft(
                k2, cfg, peft_cfg.__class__(**{**peft_cfg.__dict__, "lora_rank": self.max_rank})
            )
            self._het_fns = {}
            for r in set(self.device_rank):
                pc = peft_cfg.__class__(**{**peft_cfg.__dict__, "lora_rank": r})
                self._het_fns[r] = make_client_fns(
                    cfg, pc, stld_cfg, train_cfg, stack_mode=stack_mode
                )

    # ------------------------------------------------------------------ run
    def run(self, rounds: Optional[int] = None, target_accuracy: Optional[float] = None) -> SimResult:
        fed = self.fed_cfg
        rounds = rounds or fed.rounds
        hist = {k: [] for k in (
            "time", "acc", "loss", "rate", "active", "traffic", "energy", "memory"
        )}
        cum_time = 0.0
        num_classes = jnp.arange(self.task.num_classes)

        for rnd in range(rounds):
            cohort = self.rng.choice(
                fed.num_devices, size=min(fed.devices_per_round, fed.num_devices), replace=False
            )
            n = len(cohort)
            if self.configurator is not None:
                rates = self.configurator.next_round(n)
            elif self.strategy.stld:
                rates = [self.strategy.fixed_rate] * n
            else:
                rates = [0.0] * n

            adaopt_depth = self.cfg.num_layers
            if self.strategy.adaopt:
                adaopt_depth = min(
                    self.cfg.num_layers,
                    2 + (rnd // self.strategy.adaopt_grow_every) * 2,
                )

            round_accs, round_losses, round_times = [], [], []
            round_traffic = round_energy = 0.0
            round_mem = 0.0
            active_fracs = []
            client_updates, client_masks, client_ranks = [], [], []

            for i, dev in enumerate(cohort):
                dev = int(dev)
                out = self._run_device(
                    dev, rates[i], num_classes, adaopt_depth
                )
                peft_i, metrics, importance, acc = out
                active_frac = float(metrics["active_layers"]) / self.cfg.num_layers
                active_fracs.append(active_frac)
                round_accs.append(acc)
                round_losses.append(float(metrics["loss"]))

                if self.strategy.ptls:
                    k = max(1, int(fed.ptls_share_fraction * self.cfg.num_layers))
                    mask = np.asarray(ptls.shared_layer_mask(importance, k))
                else:
                    mask = np.ones((self.cfg.num_layers,), dtype=bool)
                client_updates.append(peft_i)
                client_masks.append(mask)
                if self.strategy.hetlora:
                    client_ranks.append(self.device_rank[dev])

                share_frac = float(mask.mean())
                cost = self.system.round_cost(
                    device=self.device_profile[dev],
                    bandwidth_mbps=sample_bandwidth(self.rng),
                    batch=fed.batch_size,
                    seq=self.task.seq_len,
                    local_steps=fed.local_steps,
                    peft=True,
                    active_fraction=active_frac if self.strategy.stld else 1.0,
                    share_fraction=share_frac,
                )
                round_times.append(cost.total_time_s)
                round_traffic += cost.traffic_mb
                round_energy += cost.energy_j
                round_mem = max(round_mem, cost.memory_gb)

                self.device_peft[dev] = peft_i
                if not hasattr(self, "_last_mask"):
                    self._last_mask = {}
                self._last_mask[dev] = mask

            # ---------------------------------------------------- aggregate
            if self.strategy.hetlora:
                self.global_peft = server_lib.hetlora_aggregate(
                    client_updates, client_ranks, self.max_rank
                )
            elif self.strategy.ptls:
                masks = np.stack(client_masks)
                self.global_peft = server_lib.ptls_aggregate(
                    client_updates, masks, self.global_peft
                )
            else:
                self.global_peft = server_lib.fedavg(client_updates)

            # ------------------------------------------------------- report
            round_wall = max(round_times)  # synchronous round
            cum_time += round_wall
            mean_acc = float(np.mean(round_accs))
            if self.configurator is not None:
                gains = []
                for i, dev in enumerate(cohort):
                    prev = self._prev_acc.get(int(dev), 1.0 / self.task.num_classes)
                    gains.append(max(round_accs[i] - prev, 0.0))
                self.configurator.report(rates, gains, round_times)
            for i, dev in enumerate(cohort):
                self._prev_acc[int(dev)] = round_accs[i]

            hist["time"].append(cum_time)
            hist["acc"].append(mean_acc)
            hist["loss"].append(float(np.mean(round_losses)))
            hist["rate"].append(float(np.mean(rates)))
            hist["active"].append(float(np.mean(active_fracs)))
            hist["traffic"].append(round_traffic)
            hist["energy"].append(round_energy)
            hist["memory"].append(round_mem)

            if target_accuracy is not None and mean_acc >= target_accuracy:
                break

        result = SimResult(
            rounds=len(hist["time"]),
            cum_time_s=np.asarray(hist["time"]),
            accuracy=np.asarray(hist["acc"]),
            loss=np.asarray(hist["loss"]),
            rates=np.asarray(hist["rate"]),
            active_fraction=np.asarray(hist["active"]),
            traffic_mb=np.asarray(hist["traffic"]),
            energy_j=np.asarray(hist["energy"]),
            memory_gb=np.asarray(hist["memory"]),
        )
        result.final_accuracy = self.final_accuracy(num_classes)
        return result

    # ------------------------------------------------------------ internals
    def _device_start_peft(self, dev: int):
        """Shared layers from the global model; personalized layers local."""
        if dev not in self.device_peft or not self.strategy.ptls:
            if self.strategy.hetlora:
                return server_lib.truncate_lora_rank(self.global_peft, self.device_rank[dev])
            return self.global_peft
        own = self.device_peft[dev]
        # device keeps its own layers; refresh from global (download)
        mixed = []
        for l in range(self.cfg.num_layers):
            mixed.append(self.global_peft[l] if self._is_shared(dev, l) else own[l])
        return mixed

    def _is_shared(self, dev: int, l: int) -> bool:
        mask = getattr(self, "_last_mask", {}).get(dev)
        return True if mask is None else bool(mask[l])

    def _run_device(self, dev: int, rate: float, num_classes, adaopt_depth: int):
        ds = self.devices[dev]
        fed = self.fed_cfg
        start_peft = self._device_start_peft(dev)
        if self.strategy.hetlora:
            rank = self.device_rank[dev]
            local_round, evaluate = self._het_fns[rank]
        else:
            local_round, evaluate = self.local_round, self.evaluate

        batches = list(ds.train_batches(fed.batch_size, fed.local_steps))
        stacked = {
            k: jnp.asarray(np.stack([b[k] for b in batches]))
            for k in ("tokens", "targets", "mask")
        }
        self.key, kr = jax.random.split(self.key)
        opt_state = adamw_init(start_peft)
        num_active = None
        if self.stld_cfg.mode == "gather" and self.strategy.stld:
            from repro.core import stld as stld_lib

            num_active = stld_lib.static_active_count(
                rate, self.cfg.num_layers, self.stld_cfg.gather_bucket,
                self.stld_cfg.min_active_layers,
            )
        peft_i, _, metrics, importance = local_round(
            self.base_params,
            start_peft,
            opt_state,
            stacked,
            jnp.asarray(rate, dtype=jnp.float32),
            kr,
            jnp.asarray(self._global_step, dtype=jnp.int32),
            num_active=num_active,
        )
        self._global_step += fed.local_steps

        if self.strategy.adaopt and adaopt_depth < self.cfg.num_layers:
            # progressive depth: layers beyond the active depth keep their
            # incoming values (their adapter updates are discarded)
            peft_i = [
                peft_i[l] if l < adaopt_depth else start_peft[l]
                for l in range(self.cfg.num_layers)
            ]

        val = ds.val_batch()
        acc = float(
            evaluate(
                self.base_params,
                peft_i,
                jnp.asarray(val["tokens"]),
                jnp.asarray(val["labels"]),
                num_classes,
            )
        )
        return peft_i, metrics, importance, acc

    def final_accuracy(self, num_classes) -> float:
        """Paper protocol: mean accuracy across ALL devices' local test sets,
        each device using its personalized model (global for non-participants)."""
        accs = []
        for dev in range(self.fed_cfg.num_devices):
            peft_d = self.device_peft.get(dev, self.global_peft)
            if self.strategy.hetlora and dev not in self.device_peft:
                peft_d = server_lib.truncate_lora_rank(self.global_peft, self.device_rank[dev])
            _, evaluate = (
                self._het_fns[self.device_rank[dev]]
                if self.strategy.hetlora
                else (None, self.evaluate)
            )
            val = self.devices[dev].val_batch()
            accs.append(
                float(
                    evaluate(
                        self.base_params,
                        peft_d,
                        jnp.asarray(val["tokens"]),
                        jnp.asarray(val["labels"]),
                        num_classes,
                    )
                )
            )
        return float(np.mean(accs))
