"""Deprecated god-class shim over the hook-based federated algorithm API.

The 580-line ``FederatedSimulator.run()`` monolith has been decomposed into
three composable layers (see ``README.md`` for the full tour):

* **Algorithms** (:mod:`repro.federated.algorithms`) — each paper method and
  ablation is a registered :class:`FederatedAlgorithm` subclass exposing
  five lifecycle hooks: ``configure_round`` (cohort + dropout rates),
  ``client_init`` (per-device start tree), ``cohort_step`` (train the
  cohort), ``aggregate`` (share masks + global merge), ``report``
  (system-model costs + bandit feedback).  New methods are ~50-line
  plugins; no flags are threaded through a central loop.
* **Engine** (:mod:`repro.federated.engine`) — the batched/sequential
  cohort execution strategies behind ``cohort_step``, unchanged numerics
  from the PR-1 batched engine.
* **Runner** (:mod:`repro.federated.runner`) — the engine-agnostic round
  loop threading an immutable :class:`~repro.federated.state.RoundState`
  through the hooks, with ``target_accuracy`` early stop, multi-seed
  replication, and checkpoint save/resume.

This module keeps the legacy surface importable: :class:`Strategy` (the old
boolean flag table), ``METHODS`` (now derived from the algorithm registry),
and :class:`FederatedSimulator`, which emits a :class:`DeprecationWarning`
and delegates to the new runner.  ``tests/test_api.py`` asserts the shim
path produces results identical to :func:`repro.api.experiment`, and
``tests/test_method_parity.py`` proves the runner reproduces the
pre-refactor ``run()`` arrays bit-for-bit for every registered method.

New code should use :func:`repro.api.experiment` instead::

    from repro import api
    result = api.experiment(method="droppeft", rounds=10, seed=0)
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Optional

from repro.federated.algorithms import (
    DropPEFT,
    FedAdaOPT,
    FedHetLoRA,
    FederatedAlgorithm,
)
from repro.federated.runner import ExperimentRunner, SimResult

__all__ = [
    "Strategy",
    "METHODS",
    "SimResult",
    "FederatedSimulator",
    "algorithm_from_strategy",
]


@dataclass
class Strategy:
    """Deprecated flag table describing a paper method/ablation.

    Kept only so existing callers (and the legacy ``METHODS`` dict) continue
    to work; the flags map onto a registered
    :class:`~repro.federated.algorithms.FederatedAlgorithm` subclass via
    :func:`algorithm_from_strategy`.
    """

    name: str = "droppeft"
    stld: bool = True
    configurator: bool = True
    ptls: bool = True
    fixed_rate: float = 0.5          # used when configurator is off
    hetlora: bool = False            # FedHetLoRA baseline
    hetlora_ranks: tuple = (4, 8, 16)
    adaopt: bool = False             # FedAdaOPT progressive-depth baseline
    adaopt_grow_every: int = 5


METHODS: Dict[str, Strategy] = {
    "fedlora": Strategy("fedlora", stld=False, configurator=False, ptls=False),
    "fedadapter": Strategy("fedadapter", stld=False, configurator=False, ptls=False),
    "fedhetlora": Strategy(
        "fedhetlora", stld=False, configurator=False, ptls=False, hetlora=True
    ),
    "fedadaopt": Strategy(
        "fedadaopt", stld=False, configurator=False, ptls=False, adaopt=True
    ),
    "droppeft": Strategy("droppeft"),
    "droppeft_b1": Strategy("droppeft_b1", stld=False),            # w/o STLD
    "droppeft_b2": Strategy("droppeft_b2", configurator=False),    # fixed rate
    "droppeft_b3": Strategy("droppeft_b3", ptls=False),            # w/o PTLS
}


def algorithm_from_strategy(strategy: Strategy) -> FederatedAlgorithm:
    """Map a legacy flag table onto an algorithm instance."""
    if strategy.hetlora:
        algo: FederatedAlgorithm = FedHetLoRA(ranks=strategy.hetlora_ranks)
    elif strategy.adaopt:
        algo = FedAdaOPT(grow_every=strategy.adaopt_grow_every)
    else:
        # DropPEFT with every component toggleable covers the whole
        # homogeneous-rank, full-depth method family (incl. FedLoRA/Adapter)
        algo = DropPEFT(
            stld=strategy.stld,
            configurator=strategy.configurator,
            ptls=strategy.ptls,
            fixed_rate=strategy.fixed_rate,
        )
    algo.name = strategy.name
    return algo


class FederatedSimulator:
    """Deprecated: construct experiments through :mod:`repro.api` instead.

    Delegates to :class:`~repro.federated.runner.ExperimentRunner`; results
    are identical to the pre-refactor implementation (asserted bit-for-bit
    in ``tests/test_method_parity.py``).
    """

    def __init__(
        self,
        cfg,
        peft_cfg,
        stld_cfg,
        fed_cfg,
        train_cfg,
        *,
        strategy: "Strategy | str" = "droppeft",
        task=None,
        cost_cfg=None,
        seed: int = 0,
        cohort_mode: str = "auto",
    ):
        warnings.warn(
            "FederatedSimulator is deprecated; use repro.api.experiment(...) "
            "or repro.federated.ExperimentRunner",
            DeprecationWarning,
            stacklevel=2,
        )
        self.strategy = METHODS[strategy] if isinstance(strategy, str) else strategy
        self._runner = ExperimentRunner(
            cfg,
            peft_cfg,
            stld_cfg,
            fed_cfg,
            train_cfg,
            algorithm=algorithm_from_strategy(self.strategy),
            task=task,
            cost_cfg=cost_cfg,
            seed=seed,
            cohort_mode=cohort_mode,
        )

    def run(
        self, rounds: Optional[int] = None, target_accuracy: Optional[float] = None
    ) -> SimResult:
        return self._runner.run(rounds=rounds, target_accuracy=target_accuracy)

    # legacy attribute surface, delegated to the runner
    @property
    def runner(self) -> ExperimentRunner:
        return self._runner

    @property
    def cohort_mode(self) -> str:
        return self._runner.cohort_mode

    @property
    def task(self):
        return self._runner.ctx.task

    @property
    def devices(self):
        return self._runner.ctx.devices

    @property
    def global_peft(self):
        return self._runner.state.global_peft

    @property
    def device_peft(self):
        return self._runner.state.device_peft
