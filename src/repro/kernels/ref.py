"""Pure-jnp oracles for the Pallas kernels (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q,k,v: (B, H, S, D) -> (B, H, S, D).  Naive masked softmax attention."""
    s = q.shape[2]
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * (d**-0.5)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), dtype=bool)
    if causal:
        ok = ok & (kpos <= qpos)
    if window is not None and window > 0:
        ok = ok & (kpos > qpos - window)
    scores = jnp.where(ok[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)


def wkv6_ref(r, k, v, logw, u):
    """Sequential WKV6 recurrence.  r,k,v,logw: (B,S,H,K); u: (H,K)."""
    b, s, h, kd = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, logw))
    uf = u.astype(jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,K)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, state + uf[None, :, :, None] * kv)
        state = jnp.exp(w_t)[..., None] * state + kv
        return state, out

    s0 = jnp.zeros((b, h, kd, kd), dtype=jnp.float32)
    xs = tuple(t.swapaxes(0, 1) for t in (rf, kf, vf, wf))
    _, outs = jax.lax.scan(step, s0, xs)
    return outs.swapaxes(0, 1).astype(r.dtype)  # (B,S,H,V)


def mamba_scan_ref(dt, x, bmat, cmat, a, dvec):
    """Sequential selective scan.  dt,x: (B,S,D); bmat,cmat: (B,S,N);
    a: (D,N); dvec: (D,)."""
    dtf, xf = dt.astype(jnp.float32), x.astype(jnp.float32)
    bf, cf = bmat.astype(jnp.float32), cmat.astype(jnp.float32)
    af, df = a.astype(jnp.float32), dvec.astype(jnp.float32)
    b_sz, s, d = x.shape
    n = bmat.shape[-1]

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp  # (B,D), (B,D), (B,N), (B,N)
        a_t = jnp.exp(dt_t[..., None] * af[None])          # (B,D,N)
        h = a_t * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.sum(h * c_t[:, None, :], axis=-1) + df[None] * x_t
        return h, y

    h0 = jnp.zeros((b_sz, d, n), dtype=jnp.float32)
    xs = tuple(t.swapaxes(0, 1) for t in (dtf, xf, bf, cf))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)


def lora_matmul_ref(x, w, a, b, *, alpha: float = 1.0):
    xf = x.astype(jnp.float32)
    return (
        xf @ w.astype(jnp.float32) + alpha * (xf @ a.astype(jnp.float32)) @ b.astype(jnp.float32)
    ).astype(x.dtype)


@jax.jit
def _segmented_row_ref(xi, w, ai, bi, rank):
    main = jax.lax.dot(xi, w, preferred_element_type=jnp.float32)
    t = jax.lax.dot(xi, ai, preferred_element_type=jnp.float32)
    t = jnp.where(jnp.arange(ai.shape[-1])[None, :] < rank, t, 0.0)
    side = jax.lax.dot(t.astype(xi.dtype), bi, preferred_element_type=jnp.float32)
    return (main + side).astype(xi.dtype)


def segmented_lora_ref(x, w, a, b, idx, ranks):
    """Per-request adapter-switching oracle for the segmented kernel.

    One row at a time — exactly what a server without multi-tenant batching
    does: look up the row's adapter, run the plain fused-LoRA math with it.
    Host loop over rows (``idx`` concrete); the row body mirrors the
    kernel's op order (f32 dots over the full ``r_max`` bottleneck with the
    rank tail masked to zero, cast back to the input dtype between the two
    side dots) so float32 inputs compare bit-for-bit.  The row body is
    jitted for the same reason: XLA fuses the final ``main + side`` add
    into the gemm epilogue, which rounds differently from an eager
    compute-then-add — both sides must go through the same rewrite.
    Slicing ``a[s][:, :r]`` instead of masking is mathematically identical
    but regroups the f32 reduction, so the true-rank equivalence is an
    allclose property, not a bitwise one.  The per-adapter LoRA scale is
    pre-folded into ``b`` (see ``segmented_lora_pallas``) — no scalar
    multiply appears here either.
    """
    import numpy as np

    rows = []
    for i, s in enumerate(np.asarray(idx).tolist()):
        rows.append(_segmented_row_ref(x[i : i + 1], w, a[s], b[s], ranks[s]))
    return jnp.concatenate(rows, axis=0)
