"""Flash-decode kernel: single-query attention against a long KV cache.

The serving hot path (decode_32k / long_500k).  TPU adaptation of
flash-decoding: the grid walks (batch*kv_head, kv_blocks) with the kv axis
innermost (sequential on TPU), carrying the online-softmax statistics for
the whole q-head GROUP in VMEM scratch — the GQA group shares its KV block
loads, so HBM traffic is exactly one cache read per step (the roofline
floor for decode, EXPERIMENTS.md §Roofline).

Masking: ``k_positions`` carries each slot's absolute position (ring-buffer
aware), so causal + sliding-window checks work on wrapped caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _decode_kernel(
    q_ref,        # (1, rep, d)     — the kv-head's query group
    k_ref,        # (1, block_k, d)
    v_ref,        # (1, block_k, d)
    kpos_ref,     # (1, block_k)
    o_ref,        # (1, rep, d)
    m_scratch,    # (rep, 1)
    l_scratch,    # (rep, 1)
    acc_scratch,  # (rep, d)
    *,
    scale: float,
    num_kv_blocks: int,
    q_position: int,
    window: int,
):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0].astype(jnp.float32)            # (rep, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    kpos = kpos_ref[0]                          # (bk,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                    # (rep, bk)
    ok = kpos <= q_position
    if window > 0:
        ok = jnp.logical_and(ok, kpos > q_position - window)
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_scratch[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scratch[...] = alpha * l_scratch[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scratch[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scratch[...] / jnp.maximum(l_scratch[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_pallas(
    q,
    k_cache,
    v_cache,
    k_positions,
    q_position,
    *,
    window: int | None = None,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    """q: (B, H, D); k_cache/v_cache: (B, S, KV, D); k_positions: (S,) abs
    slot positions; q_position: int.  Returns (B, H, D)."""
    b, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    rep = h // kv
    block_k = min(block_k, s)
    s_pad = -(-s // block_k) * block_k
    if s_pad != s:
        pad4 = lambda t: jnp.pad(t, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        k_cache, v_cache = pad4(k_cache), pad4(v_cache)
        k_positions = jnp.pad(k_positions, (0, s_pad - s), constant_values=jnp.iinfo(jnp.int32).max)
    nk = s_pad // block_k

    # regroup: (B*KV, rep, d) queries; (B*KV, S, d) caches
    qg = q.reshape(b, kv, rep, d).reshape(b * kv, rep, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * kv, s_pad, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * kv, s_pad, d)
    kp = jnp.broadcast_to(k_positions[None], (b * kv, s_pad)).astype(jnp.int32)

    kernel = functools.partial(
        _decode_kernel,
        scale=d**-0.5,
        num_kv_blocks=nk,
        q_position=int(q_position) if not hasattr(q_position, "dtype") else q_position,
        window=window or 0,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * kv, nk),
        in_specs=[
            pl.BlockSpec((1, rep, d), lambda bh, ik: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k), lambda bh, ik: (bh, ik)),
        ],
        out_specs=pl.BlockSpec((1, rep, d), lambda bh, ik: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, rep, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kf, vf, kp)
    return out.reshape(b, kv, rep, d).reshape(b, h, d)
