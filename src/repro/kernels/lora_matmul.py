"""Fused LoRA matmul: y = x @ W + alpha * (x @ A) @ B.

The PEFT hot path.  Fusing the rank-r side branch into the main matmul's
epilogue means the (M, r) intermediate never round-trips HBM and W is read
exactly once.  Grid (M blocks, N blocks); K is kept whole per block (the
assigned architectures have K = d_model <= 8192: an (bm=128, K) x (K, bn=128)
working set stays well inside the ~16 MB/core VMEM budget in bf16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128


def _lora_kernel(x_ref, w_ref, a_ref, b_ref, o_ref, *, alpha: float):
    x = x_ref[...]
    main = jax.lax.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    t = jax.lax.dot(x, a_ref[...], preferred_element_type=jnp.float32)  # (bm, r)
    side = jax.lax.dot(
        t.astype(x.dtype), b_ref[...], preferred_element_type=jnp.float32
    )
    o_ref[...] = (main + alpha * side).astype(o_ref.dtype)


def lora_matmul_pallas(
    x,
    w,
    a,
    b,
    *,
    alpha: float = 1.0,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    interpret=None,
):
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N).  Returns (M, N).

    ``interpret=None`` resolves from the cached backend query — interpret
    mode on CPU, the compiled kernel on TPU/GPU — so direct callers get the
    real kernel off-CPU instead of a silently interpreted one.
    """
    if interpret is None:
        from repro.kernels.ops import is_cpu_backend

        interpret = is_cpu_backend()
    m, kdim = x.shape
    n = w.shape[1]
    r = a.shape[1]
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    m_pad = -(-m // block_m) * block_m
    n_pad = -(-n // block_n) * block_n
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    if n_pad != n:
        w = jnp.pad(w, ((0, 0), (0, n_pad - n)))
        b = jnp.pad(b, ((0, 0), (0, n_pad - n)))

    kernel = functools.partial(_lora_kernel, alpha=alpha)
    out = pl.pallas_call(
        kernel,
        grid=(m_pad // block_m, n_pad // block_n),
        in_specs=[
            pl.BlockSpec((block_m, kdim), lambda im, inn: (im, 0)),
            pl.BlockSpec((kdim, block_n), lambda im, inn: (0, inn)),
            pl.BlockSpec((kdim, r), lambda im, inn: (0, 0)),
            pl.BlockSpec((r, block_n), lambda im, inn: (0, inn)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda im, inn: (im, inn)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), x.dtype),
        interpret=interpret,
    )(x, w, a, b)
    return out[:m, :n]
