"""Public jit'd wrappers around the Pallas kernels.

``impl='pallas'`` runs the kernels (interpret mode on CPU, native on TPU);
``impl='xla'`` dispatches to the pure-jnp reference path — the default for
dry-run lowering since Pallas does not lower to the XLA CPU backend.

The public functions resolve the backend question (``interpret`` =
running-on-CPU) *outside* the traced region and pass the answer through a
static argument of the inner jitted program.  Querying ``jax.devices()``
during trace would bake the platform into the compiled program without
making it part of the cache key — a stale answer after a backend switch.
"""
from __future__ import annotations

from functools import cache, partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lora_matmul import lora_matmul_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.rwkv6_scan import wkv6_pallas


@cache
def is_cpu_backend() -> bool:
    """Cached backend query: does Pallas need interpret mode here?

    Safe to cache for the process lifetime — JAX fixes the default backend
    at first use.  The kernels' ``interpret=None`` defaults resolve through
    this, so GPU/TPU runs compile the real kernels while CPU CI keeps the
    interpret path, without baking an uncached env query into traced code.
    """
    return jax.devices()[0].platform == "cpu"


_is_cpu = is_cpu_backend


@partial(
    jax.jit,
    static_argnames=("causal", "window", "impl", "block_q", "block_k", "interpret"),
)
def _flash_attention(q, k, v, *, causal, window, impl, block_q, block_k, interpret):
    h, kv = q.shape[1], k.shape[1]
    if kv != h:
        rep = h // kv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if impl == "xla":
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window=None,
    impl: str = "pallas",
    block_q: int = 128,
    block_k: int = 128,
):
    """q: (B, H, S, D); k, v: (B, KV, S, D) — GQA broadcast handled here."""
    return _flash_attention(
        q, k, v, causal=causal, window=window, impl=impl,
        block_q=block_q, block_k=block_k, interpret=_is_cpu(),
    )


@partial(jax.jit, static_argnames=("impl", "chunk", "interpret"))
def _wkv6(r, k, v, logw, u, *, impl, chunk, interpret):
    if impl == "xla":
        return ref.wkv6_ref(r, k, v, logw, u)
    return wkv6_pallas(r, k, v, logw, u, chunk=chunk, interpret=interpret)


def wkv6(r, k, v, logw, u, *, impl: str = "pallas", chunk: int = 16):
    return _wkv6(r, k, v, logw, u, impl=impl, chunk=chunk, interpret=_is_cpu())


@partial(jax.jit, static_argnames=("impl", "chunk", "d_block", "interpret"))
def _mamba_scan(dt, x, bmat, cmat, a, dvec, *, impl, chunk, d_block, interpret):
    if impl == "xla":
        return ref.mamba_scan_ref(dt, x, bmat, cmat, a, dvec)
    d = x.shape[-1]
    d_block = min(d_block, d)
    while d % d_block:
        d_block //= 2
    return mamba_scan_pallas(
        dt, x, bmat, cmat, a, dvec, chunk=chunk, d_block=max(1, d_block),
        interpret=interpret,
    )


def mamba_scan(dt, x, bmat, cmat, a, dvec, *, impl: str = "pallas", chunk: int = 64, d_block: int = 256):
    return _mamba_scan(
        dt, x, bmat, cmat, a, dvec, impl=impl, chunk=chunk, d_block=d_block,
        interpret=_is_cpu(),
    )


@partial(jax.jit, static_argnames=("alpha", "impl", "block_m", "block_n", "interpret"))
def _lora_matmul(x, w, a, b, *, alpha, impl, block_m, block_n, interpret):
    if impl == "xla":
        return ref.lora_matmul_ref(x, w, a, b, alpha=alpha)
    return lora_matmul_pallas(
        x, w, a, b, alpha=alpha, block_m=block_m, block_n=block_n,
        interpret=interpret,
    )


def lora_matmul(x, w, a, b, *, alpha: float = 1.0, impl: str = "pallas", block_m: int = 128, block_n: int = 128):
    return _lora_matmul(
        x, w, a, b, alpha=alpha, impl=impl, block_m=block_m, block_n=block_n,
        interpret=_is_cpu(),
    )


@partial(jax.jit, static_argnames=("impl", "block_n", "interpret"))
def _segmented_lora(x, w, a, b, idx, ranks, *, impl, block_n, interpret):
    if impl == "xla":
        # gather formulation: one batched matmul chain over per-row adapters
        ar = a[idx].astype(x.dtype)          # (M, K, r_max)
        br = b[idx].astype(x.dtype)          # (M, r_max, N)
        t = jnp.einsum("mk,mkr->mr", x.astype(jnp.float32), ar.astype(jnp.float32))
        rmask = jnp.arange(a.shape[-1])[None, :] < ranks[idx][:, None]
        t = jnp.where(rmask, t, 0.0)
        side = jnp.einsum(
            "mr,mrn->mn", t.astype(x.dtype).astype(jnp.float32), br.astype(jnp.float32)
        )
        main = x.astype(jnp.float32) @ w.astype(jnp.float32)
        return (main + side).astype(x.dtype)
    from repro.kernels.segmented_lora import segmented_lora_pallas

    return segmented_lora_pallas(
        x, w, a, b, idx, ranks, block_n=block_n, interpret=interpret
    )


def segmented_lora(x, w, a, b, idx, ranks, *, impl: str = "pallas", block_n: int = 128):
    """Multi-tenant LoRA matmul: row i uses adapter ``idx[i]`` from the
    stacked pool.  x: (M, K); w: (K, N); a: (NA, K, r_max);
    b: (NA, r_max, N) with per-adapter scale pre-folded in; idx: (M,);
    ranks: (NA,)."""
    return _segmented_lora(
        x, w, a, b, idx, ranks, impl=impl, block_n=block_n,
        interpret=_is_cpu(),
    )
