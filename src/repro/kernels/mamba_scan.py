"""Mamba selective-scan kernel.

TPU adaptation of the CUDA selective-scan: the hidden state h (d_block x N)
is VMEM-resident while the grid walks (batch, d_inner blocks, time chunks);
discretisation (a = exp(dt*A), b = dt*B*x) happens inside the kernel so the
(B, S, D, N) tensors the XLA associative-scan path materialises never touch
HBM.  The inner time loop is a ``fori_loop`` over the chunk — elementwise
VPU work on (d_block, N) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64
DEFAULT_D_BLOCK = 256


def _scan_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, d_ref, o_ref, h_scratch, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    dt = dt_ref[0].astype(jnp.float32)   # (C, bd)
    x = x_ref[0].astype(jnp.float32)     # (C, bd)
    bmat = b_ref[0].astype(jnp.float32)  # (C, N)
    cmat = c_ref[0].astype(jnp.float32)  # (C, N)
    a = a_ref[...].astype(jnp.float32)   # (bd, N)
    dvec = d_ref[...].astype(jnp.float32)  # (1, bd)

    def step(t, carry):
        h, ys = carry
        dt_t = dt[t][:, None]             # (bd, 1)
        a_t = jnp.exp(dt_t * a)           # (bd, N)
        b_t = (dt_t * x[t][:, None]) * bmat[t][None, :]
        h = a_t * h + b_t
        y_t = jnp.sum(h * cmat[t][None, :], axis=-1) + dvec[0] * x[t]
        ys = jax.lax.dynamic_update_slice(ys, y_t[None, :], (t, 0))
        return h, ys

    h0 = h_scratch[...]
    ys0 = jnp.zeros_like(o_ref[0], dtype=jnp.float32)
    h_final, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_scratch[...] = h_final
    o_ref[0] = ys.astype(o_ref.dtype)


def mamba_scan_pallas(
    dt,
    x,
    bmat,
    cmat,
    a,
    dvec,
    *,
    chunk: int = DEFAULT_CHUNK,
    d_block: int = DEFAULT_D_BLOCK,
    interpret: bool = True,
):
    """Selective scan.

    dt, x: (B, S, D); bmat, cmat: (B, S, N); a: (D, N) (negative); dvec: (D,).
    Returns y (B, S, D) = C_t . h_t + D*x with h_t = exp(dt A) h_{t-1} + dt B x.
    """
    b, s, d = x.shape
    n = bmat.shape[-1]
    d_block = min(d_block, d)
    if d % d_block:
        raise ValueError(f"d_inner {d} must be divisible by d_block {d_block}")
    chunk = min(chunk, s)
    s_pad = -(-s // chunk) * chunk
    if s_pad != s:
        pad3 = lambda t: jnp.pad(t, ((0, 0), (0, s_pad - s), (0, 0)))
        dt, x, bmat, cmat = pad3(dt), pad3(x), pad3(bmat), pad3(cmat)
    nd = d // d_block
    nc = s_pad // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(b, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda ib, idb, ic: (ib, ic, idb)),
            pl.BlockSpec((1, chunk, d_block), lambda ib, idb, ic: (ib, ic, idb)),
            pl.BlockSpec((1, chunk, n), lambda ib, idb, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, idb, ic: (ib, ic, 0)),
            pl.BlockSpec((d_block, n), lambda ib, idb, ic: (idb, 0)),
            pl.BlockSpec((1, d_block), lambda ib, idb, ic: (0, idb)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block), lambda ib, idb, ic: (ib, ic, idb)),
        out_shape=jax.ShapeDtypeStruct((b, s_pad, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(dt, x, bmat, cmat, a, dvec.reshape(1, d))
    return out[:, :s]
