"""Blocked online-softmax (flash) attention — causal / sliding-window, GQA.

TPU adaptation: the grid is (batch*heads, q_blocks, kv_blocks) with the kv
dimension innermost; TPU grids execute sequentially over the last axis, so
the running (m, l, acc) statistics live in VMEM scratch and are carried
across kv iterations without HBM traffic.  Block shapes are MXU-aligned
(block_q x head_dim and block_k x head_dim tiles, head_dim padded to 128 by
the wrapper when needed).  Blocks strictly above the causal diagonal (or
outside the sliding window) are skipped with ``pl.when`` — no MXU work is
issued for them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scratch,
    l_scratch,
    acc_scratch,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
    causal: bool,
    window: int,
    seq_len: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    q_start = iq * block_q
    k_start = ik * block_k

    @pl.when(ik == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    # block-level relevance: skip fully-masked blocks
    relevant = True
    if causal:
        relevant = k_start <= q_start + block_q - 1
    if window > 0:
        # newest query in the block attends back `window`; if the whole kv
        # block is older than that, skip.
        relevant = jnp.logical_and(relevant, k_start + block_k > q_start - window + 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < seq_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]  # (block_q, 1)
        l_prev = l_scratch[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (block_q, block_k)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_scratch[...]
        o_ref[0] = (acc_scratch[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    """q: (B, H, S, D); k, v: (B, H, S, D) (GQA repeat done by the wrapper).

    Returns (B, H, S, D).
    """
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    s_pad = -(-s // max(block_q, block_k)) * max(block_q, block_k)
    if s_pad != s:
        pad = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
        q, k, v = pad(q), pad(k), pad(v)
    nq = s_pad // block_q
    nk = s_pad // block_k

    kernel = functools.partial(
        _attn_kernel,
        scale=d**-0.5,
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=nk,
        causal=causal,
        window=window or 0,
        seq_len=s,
    )
    qf = q.reshape(b * h, s_pad, d)
    kf = k.reshape(b * h, s_pad, d)
    vf = v.reshape(b * h, s_pad, d)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s_pad, d)[:, :, :s]
