"""RWKV6 chunked WKV recurrence kernel.

TPU adaptation of the (sequential, SM-local) CUDA WKV kernel: the per-head
state S (K x V) stays resident in VMEM scratch while the grid walks the
sequence chunk-by-chunk (TPU grids are sequential over the last axis).
Within a chunk everything is MXU matmuls via the bounded log-decay division
trick (per-step log decay clamped to [-DECAY_CLAMP, 0), see
``repro.nn.rwkv``); across chunks only the (K, V) state carries — no
(B, S, K, V) tensor ever exists in HBM, which is the whole point of the
kernel (the XLA fallback materialises per-chunk states).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 16


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_scratch, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scratch[...] = jnp.zeros_like(state_scratch)

    r = r_ref[0].astype(jnp.float32)    # (C, K)
    kk = k_ref[0].astype(jnp.float32)   # (C, K)
    v = v_ref[0].astype(jnp.float32)    # (C, V)
    lw = lw_ref[0].astype(jnp.float32)  # (C, K), < 0
    u = u_ref[0].astype(jnp.float32)    # (1, K) bonus

    lcum = jnp.cumsum(lw, axis=0)       # inclusive within-chunk decay prefix
    lprev = lcum - lw                   # exclusive
    ltot = lcum[-1:]                    # (1, K)

    q_ = r * jnp.exp(lprev)             # bounded
    kappa = kk * jnp.exp(-lcum)         # bounded by e^{C*clamp}
    kappa_end = kk * jnp.exp(ltot - lcum)

    amat = jax.lax.dot_general(
        q_, kappa, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C)
    ii = jax.lax.broadcasted_iota(jnp.int32, amat.shape, 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, amat.shape, 1)
    amat = jnp.where(jj < ii, amat, 0.0)  # strictly lower triangular
    diag = jnp.sum(r * u * kk, axis=-1, keepdims=True)  # (C, 1) bonus term

    s_in = state_scratch[...]  # (K, V)
    intra = jax.lax.dot(amat, v, preferred_element_type=jnp.float32)
    inter = jax.lax.dot(q_, s_in, preferred_element_type=jnp.float32)
    o_ref[0] = (intra + diag * v + inter).astype(o_ref.dtype)

    state_scratch[...] = jnp.exp(ltot).T * s_in + jax.lax.dot_general(
        kappa_end, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def wkv6_pallas(r, k, v, logw, u, *, chunk: int = DEFAULT_CHUNK, interpret: bool = True):
    """r,k,v,logw: (B, S, H, K); u: (H, K).  Returns out (B, S, H, K).

    logw must already be clamped to [-DECAY_CLAMP, 0) by the caller
    (``repro.nn.rwkv`` does this); the division trick inside the kernel is
    only numerically safe under that contract.
    """
    b, s, h, kd = r.shape
    s_pad = -(-s // chunk) * chunk
    if s_pad != s:
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        r, k, v = zpad(r), zpad(k), zpad(v)
        logw = jnp.pad(logw, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    nc = s_pad // chunk
    # (B*H, S, K) layout: head-major so each grid row owns one head's stream
    tr = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s_pad, kd)
    rf, kf, vf, lwf = tr(r), tr(k), tr(v), tr(logw)
    uf = jnp.broadcast_to(u[None], (b, h, kd)).reshape(b * h, 1, kd)

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, kd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, kd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, kd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, kd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, 1, kd), lambda bh, ic: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, kd), lambda bh, ic: (bh, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_pad, kd), r.dtype),
        scratch_shapes=[pltpu.VMEM((kd, kd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, uf)
    return out.reshape(b, h, s_pad, kd).transpose(0, 2, 1, 3)[:, :s]
