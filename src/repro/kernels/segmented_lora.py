"""Segmented (gathered) multi-adapter LoRA matmul — the punica/SGMV-style
serving hot path: ``y[i] = x[i] @ W + (x[i] @ A[idx[i]]) @ B[idx[i]]``.

Every row of the batch indexes its own LoRA adapter out of a stacked pool
``a: (n_adapters, K, r_max)`` / ``b: (n_adapters, r_max, N)``, so one kernel
launch serves a whole continuous batch of heterogeneous tenants.  The
adapter row indices arrive as a *scalar-prefetch* operand
(:class:`~jax.experimental.pallas.tpu.PrefetchScalarGridSpec`): the
``BlockSpec`` index maps read ``idx[i]`` to DMA exactly the one adapter each
row needs — the pool never streams through VMEM wholesale.

Rank heterogeneity (hetlora cohorts train clients at different ranks) is
served from a single pool: adapters are zero-padded to ``r_max`` and an
in-kernel rank mask zeroes the padded tail of the rank-bottleneck
intermediate.  The mask is load-bearing for slot hot-swap: a recycled pool
slot may still hold the stale tail of a higher-rank adapter, and the mask
keeps it inert without a device round-trip to zero it.

The per-adapter LoRA scaling (alpha / rank, heterogeneous under hetlora) is
**pre-folded into the pooled ``b``** when a slot is written — deliberately
not a kernel operand.  A scalar multiply adjacent to a dot is rewritten
freely by XLA (FMA fusion of ``main + s*side``, hoisting ``dot(s*t, b)`` to
``s*dot(t, b)``), each with different rounding, which breaks the bit-parity
contract between the batched kernel and the per-request reference.  With
the scale folded at swap time the traced program is dots + mask + add only.

The grid is (M rows, N blocks) — decode batches are short (M = batch), so a
one-row query block per adapter gather keeps the indexing exact; K is kept
whole per block like ``lora_matmul``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 128


def _segmented_kernel(idx_ref, ranks_ref, x_ref, w_ref, a_ref, b_ref, o_ref):
    i = pl.program_id(0)
    slot = idx_ref[i]
    x = x_ref[...]  # (1, K)
    main = jax.lax.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    t = jax.lax.dot(x, a_ref[0], preferred_element_type=jnp.float32)  # (1, r_max)
    # zero the padded rank tail: 2D iota (TPU requires >= 2D) vs this
    # adapter's true rank — stale values beyond it must not contribute
    rmask = jax.lax.broadcasted_iota(jnp.int32, t.shape, 1) < ranks_ref[slot]
    t = jnp.where(rmask, t, 0.0)
    side = jax.lax.dot(t.astype(x.dtype), b_ref[0], preferred_element_type=jnp.float32)
    o_ref[...] = (main + side).astype(o_ref.dtype)


def segmented_lora_pallas(
    x,
    w,
    a,
    b,
    idx,
    ranks,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret=None,
):
    """x: (M, K); w: (K, N); a: (NA, K, r_max); b: (NA, r_max, N) with the
    per-adapter alpha/rank scale pre-folded in; idx: (M,) int32 adapter id
    per row; ranks: (NA,) int32 true ranks.  Returns (M, N)."""
    if interpret is None:
        from repro.kernels.ops import is_cpu_backend

        interpret = is_cpu_backend()
    m, kdim = x.shape
    n = w.shape[1]
    r_max = a.shape[-1]
    block_n = min(block_n, n)
    n_pad = -(-n // block_n) * block_n
    if n_pad != n:
        w = jnp.pad(w, ((0, 0), (0, n_pad - n)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, n_pad - n)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m, n_pad // block_n),
        in_specs=[
            pl.BlockSpec((1, kdim), lambda i, j, idx, rk: (i, 0)),
            pl.BlockSpec((kdim, block_n), lambda i, j, idx, rk: (0, j)),
            pl.BlockSpec((1, kdim, r_max), lambda i, j, idx, rk: (idx[i], 0, 0)),
            pl.BlockSpec((1, r_max, block_n), lambda i, j, idx, rk: (idx[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i, j, idx, rk: (i, j)),
    )
    out = pl.pallas_call(
        _segmented_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n_pad), x.dtype),
        interpret=interpret,
    )(
        idx.astype(jnp.int32),
        ranks.astype(jnp.int32),
        x,
        w,
        a,
        b,
    )
    return out[:, :n]
