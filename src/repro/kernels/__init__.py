"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three artifacts:
  * ``<name>.py`` — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling
  * ``ops.py``    — jit'd public wrappers with shape plumbing + impl select
  * ``ref.py``    — pure-jnp oracles used by the allclose test sweeps

On this CPU container kernels run in ``interpret=True`` mode (Pallas does not
lower to the XLA CPU backend); on TPU the same code JITs natively.
"""
