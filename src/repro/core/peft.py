"""PEFT methods (LoRA / Adapter / BitFit) with frozen-base param partition.

The PEFT tree mirrors the layer stack and, like it, comes in two layouts
(:mod:`repro.models.stacking`): the stacked-native layout (one leaf per
PEFT param kind with a leading ``(L, ...)`` layer axis — the default for
homogeneous stacks) and the per-layer list where ``peft[l]`` is a dict
consumed by ``layer_apply``:

* attention layers: ``{"attn": {"q"|"k"|"v"|"o": lora}, "mlp": {...},
  "adapter_attn", "adapter_mlp"}``
* mamba layers: ``{"mamba": {"in"|"out": lora}, "adapter_mlp"}``
* rwkv layers:  ``{"cm": {"up"|"down": lora}, "adapter_mlp"}``

Only the PEFT tree is trainable; the base model is frozen (paper §2.2) —
the training step takes ``(peft_params, base_params)`` and differentiates
w.r.t. the first argument only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import stacking
from repro.models.layers import layer_kind
from repro.nn.linear import init_lora
from repro.nn.mlp import init_adapter

_ATTN_DIMS = {
    "q": lambda cfg: (cfg.d_model, cfg.num_heads * cfg.resolved_head_dim),
    "k": lambda cfg: (cfg.d_model, cfg.num_kv_heads * cfg.resolved_head_dim),
    "v": lambda cfg: (cfg.d_model, cfg.num_kv_heads * cfg.resolved_head_dim),
    "o": lambda cfg: (cfg.num_heads * cfg.resolved_head_dim, cfg.d_model),
}
_MLP_DIMS = {
    "gate": lambda cfg: (cfg.d_model, cfg.d_ff),
    "up": lambda cfg: (cfg.d_model, cfg.d_ff),
    "down": lambda cfg: (cfg.d_ff, cfg.d_model),
}


def lora_scale(peft_cfg) -> float:
    return peft_cfg.lora_alpha / peft_cfg.lora_rank


def init_layer_peft(key, cfg, peft_cfg, l: int) -> dict:
    kind = layer_kind(cfg, l)
    method = peft_cfg.method
    p: dict = {}
    if method == "none":
        return p
    if method == "adapter":
        k1, k2 = jax.random.split(key)
        if kind in ("attn", "encdec"):
            p["adapter_attn"] = init_adapter(k1, cfg.d_model, peft_cfg.adapter_dim)
        p["adapter_mlp"] = init_adapter(k2, cfg.d_model, peft_cfg.adapter_dim)
        return p
    if method == "bitfit":
        p["bias_attn"] = jnp.zeros((cfg.d_model,), dtype=jnp.float32)
        p["bias_mlp"] = jnp.zeros((cfg.d_model,), dtype=jnp.float32)
        return p
    if method == "lora":
        r = peft_cfg.lora_rank
        keys = iter(jax.random.split(key, 16))
        if kind in ("attn", "encdec"):
            attn = {}
            for t in peft_cfg.lora_targets:
                if t in _ATTN_DIMS:
                    d_in, d_out = _ATTN_DIMS[t](cfg)
                    attn[t] = init_lora(next(keys), d_in, d_out, r)
            if attn:
                p["attn"] = attn
            mlp = {}
            for t in peft_cfg.lora_targets:
                if t in _MLP_DIMS and not cfg.is_moe_layer(l):
                    d_in, d_out = _MLP_DIMS[t](cfg)
                    mlp[t] = init_lora(next(keys), d_in, d_out, r)
            if mlp:
                p["mlp"] = mlp
            if kind == "encdec":
                cross = {}
                for t in peft_cfg.lora_targets:
                    if t in _ATTN_DIMS:
                        d_in, d_out = _ATTN_DIMS[t](cfg)
                        cross[t] = init_lora(next(keys), d_in, d_out, r)
                if cross:
                    p["cross"] = cross
        elif kind == "mamba":
            d_in_m = cfg.mamba.expand * cfg.d_model
            p["mamba"] = {
                "in": init_lora(next(keys), cfg.d_model, 2 * d_in_m, r),
                "out": init_lora(next(keys), d_in_m, cfg.d_model, r),
            }
        elif kind == "rwkv":
            p["cm"] = {
                "up": init_lora(next(keys), cfg.d_model, cfg.d_ff, r),
                "down": init_lora(next(keys), cfg.d_ff, cfg.d_model, r),
            }
        return p
    raise ValueError(f"unknown PEFT method {method!r}")


def init_peft(key, cfg, peft_cfg, layout: str = "auto"):
    """PEFT tree index-aligned with the layer stack.

    ``layout='auto'`` (default) emits the stacked ``(L, ...)`` layout when
    every layer's PEFT dict is structurally identical, else the per-layer
    list; ``'list'``/``'stacked'`` force a layout.
    """
    n = cfg.num_layers
    keys = jax.random.split(key, n)
    per_layer = [init_layer_peft(keys[l], cfg, peft_cfg, l) for l in range(n)]
    return stacking.maybe_stack(per_layer, layout)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def flat_bytes(tree) -> int:
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree.leaves(tree))


_LORA_TARGET_MAP = {
    "q": ("attn", "wq"),
    "k": ("attn", "wk"),
    "v": ("attn", "wv"),
    "o": ("attn", "wo"),
    "gate": ("mlp", "gate"),
    "up": ("mlp", "up"),
    "down": ("mlp", "down"),
}


def _merge_one(layer, p, scale):
    layer = jax.tree.map(lambda x: x, layer)  # shallow copy
    for group in ("attn", "mlp"):
        for t, lora in (p.get(group) or {}).items():
            mod, name = _LORA_TARGET_MAP[t]
            w = layer[mod][name]["w"]
            # a @ b broadcasts over a leading stacked layer axis:
            # (L, d_in, r) @ (L, r, d_out) -> (L, d_in, d_out)
            layer[mod][name]["w"] = w + scale * (lora["a"] @ lora["b"]).astype(w.dtype)
    return layer


def merge_lora_into_base(base_layers, peft, scale: float):
    """Fold LoRA deltas into the frozen weights (deployment path):
    W' = W + scale * A @ B.  Accepts either layer layout (both trees must
    use the same one); returns the merged stack in that layout."""
    if stacking.is_stacked(base_layers):
        return _merge_one(base_layers, peft, scale)
    return [_merge_one(layer, p, scale) for layer, p in zip(base_layers, peft)]
