"""Per-layer dropout-rate distributions (paper §3.3, Fig. 6b).

Each distribution maps (mean_rate, L) -> per-layer rates P_l in [0, 1).
The paper recommends ``incremental`` (P_l grows with depth: early layers
extract low-level features consumed by later layers, so they are preserved
more reliably).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_MAX_RATE = 0.95


def drop_rates(
    distribution: str,
    mean_rate: float,
    num_layers: int,
    *,
    normal_std: float = 0.1,
    key=None,
):
    """Per-layer dropout rates with the requested mean and shape."""
    ell = jnp.arange(1, num_layers + 1, dtype=jnp.float32)
    if distribution == "uniform":
        rates = jnp.full((num_layers,), mean_rate, dtype=jnp.float32)
    elif distribution == "incremental":
        # paper: P_l = l/(L+1); generalised to arbitrary mean by scaling
        base = ell / (num_layers + 1)
        rates = base * (mean_rate / jnp.mean(base))
    elif distribution == "decay":
        base = 1.0 - ell / (num_layers + 1)
        rates = base * (mean_rate / jnp.mean(base))
    elif distribution == "normal":
        if key is None:
            key = jax.random.PRNGKey(0)
        rates = mean_rate + normal_std * jax.random.normal(key, (num_layers,))
    else:
        raise ValueError(f"unknown dropout distribution {distribution!r}")
    return jnp.clip(rates, 0.0, _MAX_RATE)


def unit_shape(distribution: str, num_layers: int, *, normal_std: float = 0.1, key=None):
    """Unclipped per-layer shape with mean 1.0; multiply by a (possibly
    traced) mean rate and clip to get the round's rates."""
    ell = jnp.arange(1, num_layers + 1, dtype=jnp.float32)
    if distribution == "uniform":
        return jnp.ones((num_layers,), dtype=jnp.float32)
    if distribution == "incremental":
        base = ell / (num_layers + 1)
    elif distribution == "decay":
        base = 1.0 - ell / (num_layers + 1)
    elif distribution == "normal":
        if key is None:
            key = jax.random.PRNGKey(0)
        base = jnp.clip(1.0 + normal_std * jax.random.normal(key, (num_layers,)), 0.05, None)
    else:
        raise ValueError(f"unknown dropout distribution {distribution!r}")
    return base / jnp.mean(base)
