"""Online exploration-exploitation configurator for dropout rates.

Faithful implementation of the paper's Algorithm 1 as a host-side (numpy)
multi-armed bandit over *discretized* dropout-rate configurations:

* the action space is narrowed per §3.3: a preset per-layer distribution
  shape (default ``incremental``) plus a discrete grid of average rates,
  so an "arm" is the scalar mean rate;
* reward of an arm = accuracy gain per unit wall-clock time, R = dA / T
  (Eq. 5), averaged over the devices that evaluated it;
* phases alternate: one EXPLORATION sweep evaluates every candidate in
  ``list_c`` (start-up list + ``n*eps`` random arms), keeps the top
  ``n*(1-eps)`` by reward within a sliding window of the latest ``size_w``
  evaluations, then EXPLOITATION reuses the best-known arm for
  ``explore_interval`` rounds.

The object is deliberately pure-python: it sits next to the federated
server loop and never enters a jit trace.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Sequence

import numpy as np


_ARM_MEMORY = 3  # recent evaluations kept per arm (staleness, paper Line 12)


@dataclass
class ArmStats:
    rate: float
    rewards: List[float] = field(default_factory=list)
    last_eval: int = -1  # round index of last evaluation (staleness)

    def add(self, r: float):
        self.rewards.append(r)
        del self.rewards[:-_ARM_MEMORY]  # keep only recent evidence

    @property
    def reward(self) -> float:
        if not self.rewards:
            return float("-inf")
        return sum(self.rewards) / len(self.rewards)


class OnlineConfigurator:
    """Algorithm 1.  ``next_round()`` -> list of mean rates (one per device);
    ``report(rates, acc_gains, times)`` feeds back rewards."""

    def __init__(
        self,
        rate_grid: Sequence[float] = (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9),
        startup: Sequence[float] = (0.2, 0.5, 0.7),
        num_candidates: int = 4,
        explore_rate: float = 0.3,
        explore_interval: int = 5,
        window_size: int = 8,
        seed: int = 0,
        rate_floor: float = 0.0,
    ):
        self.rate_grid = list(rate_grid)
        self.num_candidates = num_candidates
        self.explore_rate = explore_rate
        self.explore_interval = explore_interval
        self.window_size = window_size
        self.rate_floor = float(rate_floor)
        self._rng = random.Random(seed)
        self.arms: Dict[float, ArmStats] = {}
        self.list_c: List[float] = [r for r in startup if r >= self.rate_floor]
        self.history: List[float] = []  # evaluation order (for staleness)
        self.is_explore = True
        self._exploit_rounds_left = 0
        self._round = 0

    # ------------------------------------------------------------------ api
    def next_round(self, n_devices: int, *, as_array: bool = False):
        """Dropout mean-rates for this round's cohort.

        ``as_array=True`` returns an (N,) float32 vector ready to feed the
        batched cohort engine; otherwise a plain python list.  ``report``
        accepts either form back (float32 round-trips snap to their arms).
        """
        if self.is_explore:
            if not self.list_c:
                self._refill_candidates()
            # evaluate candidates in parallel across the cohort: round-robin
            rates = [self.list_c[i % len(self.list_c)] for i in range(n_devices)]
        else:
            rates = [self.best_rate()] * n_devices
        self._pending = sorted(set(rates))
        if as_array:
            return np.asarray(rates, dtype=np.float32)
        return rates

    def report(self, rates: Sequence[float], acc_gains: Sequence[float], times: Sequence[float]):
        """Per-device rewards R = dA / T (Eq. 5).

        Accepts python lists or numpy/jax vectors (the batched engine hands
        back float32 arrays); rates are snapped to their exact arm keys so a
        float32 round-trip cannot mint duplicate arms.
        """
        rates = self._report_keys(rates)
        acc_gains = [float(g) for g in np.asarray(acc_gains).ravel()]
        times = [float(t) for t in np.asarray(times).ravel()]
        self._round += 1
        for r, da, t in zip(rates, acc_gains, times):
            arm = self.arms.setdefault(r, ArmStats(rate=r))
            arm.add(da / max(t, 1e-9))
            arm.last_eval = self._round
            self.history.append(r)
        # sliding window: discard overly stale arms (Line 12), but never the
        # current best — exploitation must always have its winner to return
        best = self.best_rate() if self.arms else None
        recent = set(self.history[-self.window_size * max(1, len(self._pending)) :])
        for r in list(self.arms):
            if r == best:
                continue
            if r not in recent and self.arms[r].last_eval < self._round - self.window_size:
                del self.arms[r]

        if self.is_explore:
            for r in self._pending:
                if r in self.list_c:
                    self.list_c.remove(r)
            if not self.list_c:  # exploration sweep finished -> exploit winner
                self._keep_top_candidates()
                self.is_explore = False
                self._exploit_rounds_left = self.explore_interval
        else:
            self._exploit_rounds_left -= 1
            if self._exploit_rounds_left <= 0:
                self.is_explore = True
                self._refill_candidates()

    def best_rate(self) -> float:
        """Highest-reward arm at or above the rate floor.

        With no evidence yet, falls back to the feasible grid rate closest
        to 0.5 (exactly 0.5 on the default grid, preserving the historical
        default)."""
        eligible = [a for a in self.arms.values() if self._meets_floor(a.rate)]
        if not eligible:
            return self._fallback_key(self._feasible_grid())
        return max(eligible, key=lambda a: a.reward).rate

    def set_rate_floor(self, floor: float) -> None:
        """Deadline-aware mode: restrict candidate rates to ``>= floor``.

        The virtual-clock scheduler computes the floor as the smallest grid
        rate whose predicted slowest-profile round time fits the deadline —
        rates below it would always be cut off and waste exploration
        rounds.  Existing below-floor arms stop being selected and age out
        through the regular window eviction like any other idle arm."""
        self.rate_floor = float(floor)
        self.list_c = [r for r in self.list_c if self._meets_floor(r)]
        if not self.list_c:
            self._refill_candidates()

    # ------------------------------------------------------- serialization
    def state_dict(self) -> dict:
        """JSON-serializable snapshot; restoring it resumes the bandit's
        explore/exploit schedule and python RNG stream bit-exactly."""
        return {
            "arms": [
                {"rate": a.rate, "rewards": list(a.rewards), "last_eval": a.last_eval}
                for a in self.arms.values()
            ],
            "list_c": list(self.list_c),
            "history": list(self.history),
            "rate_floor": self.rate_floor,
            "is_explore": self.is_explore,
            "exploit_rounds_left": self._exploit_rounds_left,
            "round": self._round,
            "pending": list(getattr(self, "_pending", [])),
            "has_pending": hasattr(self, "_pending"),
            "rng_state": list(self._rng.getstate()),
        }

    def load_state_dict(self, state: dict) -> None:
        self.arms = {}
        for a in state["arms"]:
            key = self._key_from_json(a["rate"])
            self.arms[key] = ArmStats(
                rate=key, rewards=list(a["rewards"]), last_eval=a["last_eval"]
            )
        self.list_c = [self._key_from_json(k) for k in state["list_c"]]
        self.history = [self._key_from_json(k) for k in state["history"]]
        self.rate_floor = float(state.get("rate_floor", 0.0))
        self.is_explore = state["is_explore"]
        self._exploit_rounds_left = state["exploit_rounds_left"]
        self._round = state["round"]
        if state.get("has_pending", True):
            self._pending = [self._key_from_json(k) for k in state["pending"]]
        elif hasattr(self, "_pending"):
            del self._pending  # snapshot predates the first next_round
        rng_state = state["rng_state"]
        self._rng.setstate((rng_state[0], tuple(rng_state[1]), rng_state[2]))

    # ------------------------------------------------------------- internals
    # small arm-key hooks so a subclass can swap the key type (the joint
    # configurator keys arms by (rate, level) tuples) without touching the
    # explore/exploit machinery, which is key-agnostic
    def _meets_floor(self, key) -> bool:
        return key >= self.rate_floor

    def _fallback_key(self, grid):
        return min(grid, key=lambda r: abs(r - 0.5)) if grid else 0.5

    def _report_keys(self, rates) -> list:
        return [self._snap_rate(float(r)) for r in np.asarray(rates).ravel()]

    def _key_from_json(self, key):
        return key

    def _snap_rate(self, r: float) -> float:
        """Map a (possibly float32-degraded) rate back to its exact arm key."""
        candidates = set(self.rate_grid) | set(self.arms) | set(self.list_c) | set(
            getattr(self, "_pending", ())
        )
        if not candidates:
            return r
        best = min(candidates, key=lambda c: abs(c - r))
        return best if abs(best - r) < 1e-5 else r

    def _feasible_grid(self) -> List[float]:
        grid = [r for r in self.rate_grid if r >= self.rate_floor]
        return grid or ([max(self.rate_grid)] if self.rate_grid else [])

    def _refill_candidates(self):
        n_explore = max(1, int(self.num_candidates * self.explore_rate))
        grid = self._feasible_grid()
        fresh = [r for r in grid if r not in self.arms]
        self._rng.shuffle(fresh)
        new = fresh[:n_explore]
        if not new and grid:  # grid exhausted: resample anywhere feasible
            new = [self._rng.choice(grid) for _ in range(n_explore)]
        top = self._top_rates(self.num_candidates - len(new))
        self.list_c = list(dict.fromkeys(new + top)) or [self.best_rate()]

    def _keep_top_candidates(self):
        keep = max(1, int(self.num_candidates * (1.0 - self.explore_rate)))
        self.list_c = self._top_rates(keep) or [self.best_rate()]

    def _top_rates(self, k: int) -> List[float]:
        eligible = [a for a in self.arms.values() if self._meets_floor(a.rate)]
        ranked = sorted(eligible, key=lambda a: a.reward, reverse=True)
        return [a.rate for a in ranked[:k]]


class JointConfigurator(OnlineConfigurator):
    """Algorithm 1 over the joint (dropout rate × compression level) space.

    FedLoDrop-style: the arm is a ``(rate, level)`` tuple, so the bandit
    trades structural shrinkage (layer dropout) against bit-level shrinkage
    (uplink compression) on one reward — accuracy gain per realized
    virtual-clock second, which already reflects the compressed comm time.
    All explore/exploit machinery is inherited; only the arm-key type, the
    candidate grid (cartesian product), and the report/snap plumbing change.
    ``rate_floor`` constrains the rate axis alone.
    """

    joint = True

    def __init__(
        self,
        rate_grid: Sequence[float] = (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9),
        startup: Sequence[float] = (0.2, 0.5, 0.7),
        levels: Sequence[str] = ("none", "int8", "topk", "int8+topk"),
        **kwargs,
    ):
        self.levels = tuple(levels)
        if not self.levels:
            raise ValueError("JointConfigurator needs at least one level")
        super().__init__(rate_grid=rate_grid, startup=startup, **kwargs)
        # pair each startup rate with a cycling level: the first sweep stays
        # as long as the rate-only bandit's, and _refill_candidates explores
        # the rest of the product grid over later sweeps
        self.list_c = [
            (float(r), self.levels[i % len(self.levels)])
            for i, r in enumerate(startup)
            if float(r) >= self.rate_floor
        ]

    # ------------------------------------------------------------------ api
    def next_round(self, n_devices: int, *, as_array: bool = False):
        raise TypeError(
            "JointConfigurator draws (rate, level) arms; use next_round_joint()"
        )

    def next_round_joint(self, n_devices: int):
        """-> (rates, levels): one (dropout rate, compression level) arm per
        cohort member, round-robin over candidates while exploring."""
        if self.is_explore:
            if not self.list_c:
                self._refill_candidates()
            arms = [self.list_c[i % len(self.list_c)] for i in range(n_devices)]
        else:
            arms = [self.best_rate()] * n_devices
        self._pending = sorted(set(arms))
        # repro-lint: disable=JXH002 — arms are host tuples, never device arrays
        return [float(a[0]) for a in arms], [a[1] for a in arms]

    # ------------------------------------------------------- serialization
    def state_dict(self) -> dict:
        state = super().state_dict()
        state["joint"] = True
        state["levels"] = list(self.levels)
        return state

    # ------------------------------------------------------------- internals
    def _meets_floor(self, key) -> bool:
        return key[0] >= self.rate_floor

    def _fallback_key(self, grid):
        if not grid:
            return (0.5, self.levels[0])
        # closest-to-0.5 rate, mildest level — the joint analogue of the
        # rate-only fallback
        return min(grid, key=lambda ar: (abs(ar[0] - 0.5), self.levels.index(ar[1])))

    def _report_keys(self, arms) -> list:
        return [self._snap_arm((float(r), str(lv))) for r, lv in arms]

    def _key_from_json(self, key):
        # JSON round-trips tuples as lists
        if isinstance(key, (list, tuple)):
            return (float(key[0]), str(key[1]))
        return key

    def _snap_arm(self, arm):
        rate, level = arm
        candidates = [
            k
            for k in (
                set(self._feasible_grid())
                | set(self.arms)
                | set(self.list_c)
                | set(getattr(self, "_pending", ()))
            )
            if k[1] == level
        ]
        if not candidates:
            return arm
        best = min(candidates, key=lambda k: abs(k[0] - rate))
        return best if abs(best[0] - rate) < 1e-5 else arm

    def _feasible_grid(self) -> list:
        rates = [r for r in self.rate_grid if r >= self.rate_floor]
        if not rates:
            rates = [max(self.rate_grid)] if self.rate_grid else []
        return [(float(r), lv) for r in rates for lv in self.levels]
