"""Personalized Transformer Layer Sharing (PTLS) — paper §4.

Per-layer importance (Eq. 6): the STLD-masked average gradient norm

    I_l = (1 / sum_b (1 - d_l^b)) * sum_b g_l^b (1 - d_l^b)

High-I_l layers are *personalized* (kept local); each device uploads the k
layers with the LOWEST importance.  The server averages only overlapping
layers (Fig. 8): for layer l, new_global_l = mean over devices sharing l;
layers shared by no device keep the previous global value.

Everything here is expressed with masked means so it lowers to plain
``psum``-style reductions when run under ``shard_map`` across a device
cohort axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import stacking


def layer_grad_norms(peft_grads, num_layers: int = 0) -> jnp.ndarray:
    """L2 norm of each layer's PEFT gradient, shape ``(L,)``.

    Accepts either layout: a list (len L) of per-layer pytrees, or a
    stacked pytree whose leaves carry a leading ``(L, ...)`` layer axis.
    A homogeneous list is canonicalized to the stacked layout first so both
    layouts lower to the identical reduce subgraph and produce bit-identical
    norms (XLA fuses per-leaf scalar reduces and trailing-axis reduces
    differently).  ``num_layers`` is only consulted for leafless trees
    (PEFT method ``'none'``).
    """
    if isinstance(peft_grads, (list, tuple)):
        if stacking.is_stackable(list(peft_grads)):
            peft_grads = stacking.stack_params(list(peft_grads))
        else:
            norms = []
            for g in peft_grads:
                leaves = jax.tree.leaves(g)
                if not leaves:
                    norms.append(jnp.zeros((), dtype=jnp.float32))
                    continue
                sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
                norms.append(jnp.sqrt(sq))
            return jnp.stack(norms)
    leaves = jax.tree.leaves(peft_grads)
    if not leaves:
        if num_layers <= 0:
            raise ValueError(
                "layer_grad_norms needs num_layers for a leafless stacked "
                "tree (PEFT method 'none') — the layer count cannot be "
                "inferred from an empty pytree"
            )
        return jnp.zeros((num_layers,), dtype=jnp.float32)
    sq = sum(
        jnp.sum(
            jnp.square(x.astype(jnp.float32)), axis=tuple(range(1, x.ndim))
        )
        for x in leaves
    )
    return jnp.sqrt(sq)


class ImportanceAccumulator:
    """Running Eq.-6 accumulator over the local batches of one round."""

    @staticmethod
    def init(num_layers: int):
        return {
            "g_sum": jnp.zeros((num_layers,), dtype=jnp.float32),
            "count": jnp.zeros((num_layers,), dtype=jnp.float32),
        }

    @staticmethod
    def update(state, grad_norms, drops):
        active = 1.0 - drops.astype(jnp.float32)
        return {
            "g_sum": state["g_sum"] + grad_norms * active,
            "count": state["count"] + active,
        }

    @staticmethod
    def importance(state):
        return state["g_sum"] / jnp.maximum(state["count"], 1.0)


def shared_layer_mask(importance, k: int) -> jnp.ndarray:
    """(L,) bool — True for the k lowest-importance (shared) layers."""
    num_layers = importance.shape[0]
    k = min(k, num_layers)
    order = jnp.argsort(importance)  # ascending: least important first
    mask = jnp.zeros((num_layers,), dtype=bool)
    return mask.at[order[:k]].set(True)


def masked_layer_mean(updates, masks, prev_global, weights=None):
    """Heterogeneous aggregation (paper Fig. 8).

    Two layouts (matching the global tree's layout):

    * list layout — ``prev_global`` is a list (len L) of per-layer pytrees
      and ``updates`` a list (len L) of pytrees whose leaves carry a
      leading device axis ``(N, ...)``.  Per-layer python loop.
    * stacked layout — ``prev_global`` is a stacked pytree with ``(L, ...)``
      leaves and ``updates`` a pytree with ``(N, L, ...)`` leaves.  One
      vectorized masked reduction over the device axis, no python loop.

    masks: (N, L) bool — device n shares layer l.  ``weights`` (optional,
    (N,) positive) turns the per-layer mean into a weighted mean — used by
    the virtual-clock scheduler's staleness-discounted aggregation.  A
    weighted denominator can be < 1, so the weighted branch guards division
    with ``where(denom > 0)`` instead of ``maximum(denom, 1)``; the
    unweighted branch is untouched (bit-parity with the legacy simulator).
    Returns the new global tree in ``prev_global``'s layout.
    """
    if weights is not None:
        return _weighted_masked_layer_mean(updates, masks, prev_global, weights)
    if not isinstance(prev_global, (list, tuple)):
        m = masks.astype(jnp.float32)          # (N, L)
        denom = jnp.sum(m, axis=0)             # (L,)
        has_any = denom > 0                    # (L,)

        def avg(leaf_upd, leaf_prev):
            w = m.reshape(m.shape + (1,) * (leaf_upd.ndim - 2))
            mean = jnp.sum(leaf_upd * w, axis=0) / jnp.maximum(
                denom.reshape((-1,) + (1,) * (leaf_prev.ndim - 1)), 1.0
            )
            keep = has_any.reshape((-1,) + (1,) * (leaf_prev.ndim - 1))
            return jnp.where(keep, mean.astype(leaf_prev.dtype), leaf_prev)

        return jax.tree.map(avg, updates, prev_global)

    num_layers = len(prev_global)
    out = []
    for l in range(num_layers):
        m = masks[:, l].astype(jnp.float32)  # (N,)
        denom = jnp.sum(m)
        has_any = denom > 0

        def avg(leaf_upd, leaf_prev):
            w = m.reshape((-1,) + (1,) * (leaf_upd.ndim - 1))
            mean = jnp.sum(leaf_upd * w, axis=0) / jnp.maximum(denom, 1.0)
            return jnp.where(has_any, mean.astype(leaf_prev.dtype), leaf_prev)

        out.append(jax.tree.map(avg, updates[l], prev_global[l]))
    return out


def _weighted_masked_layer_mean(updates, masks, prev_global, weights):
    """Staleness-weighted Fig.-8 aggregation: per layer l,
    new_global_l = sum_{n shares l} w_n x_{n,l} / sum_{n shares l} w_n,
    layers shared by nobody keep the previous global value."""
    wv = jnp.asarray(weights, dtype=jnp.float32)
    if not isinstance(prev_global, (list, tuple)):
        m = masks.astype(jnp.float32) * wv[:, None]   # (N, L)
        denom = jnp.sum(m, axis=0)                    # (L,)
        has_any = denom > 0

        def avg(leaf_upd, leaf_prev):
            w = m.reshape(m.shape + (1,) * (leaf_upd.ndim - 2))
            d = denom.reshape((-1,) + (1,) * (leaf_prev.ndim - 1))
            mean = jnp.sum(leaf_upd * w, axis=0) / jnp.where(d > 0, d, 1.0)
            keep = has_any.reshape((-1,) + (1,) * (leaf_prev.ndim - 1))
            return jnp.where(keep, mean.astype(leaf_prev.dtype), leaf_prev)

        return jax.tree.map(avg, updates, prev_global)

    out = []
    for l in range(len(prev_global)):
        m = masks[:, l].astype(jnp.float32) * wv      # (N,)
        denom = jnp.sum(m)
        has_any = denom > 0

        def avg(leaf_upd, leaf_prev):
            w = m.reshape((-1,) + (1,) * (leaf_upd.ndim - 1))
            mean = jnp.sum(leaf_upd * w, axis=0) / jnp.where(denom > 0, denom, 1.0)
            return jnp.where(has_any, mean.astype(leaf_prev.dtype), leaf_prev)

        out.append(jax.tree.map(avg, updates[l], prev_global[l]))
    return out
