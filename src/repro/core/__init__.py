"""DropPEFT core: the paper's contribution as composable JAX modules.

- ``stld``         — stochastic transformer layer dropout (paper §3.2)
- ``schedules``    — per-layer dropout-rate distributions (paper Fig. 6b)
- ``configurator`` — online bandit for dropout-rate configs (paper §3.3, Alg. 1)
- ``peft``         — LoRA / Adapter / BitFit param partitioning (paper §2.2)
- ``ptls``         — personalized transformer layer sharing (paper §4)
"""
from repro.core import configurator, peft, ptls, schedules, stld

__all__ = ["configurator", "peft", "ptls", "schedules", "stld"]
