"""Stochastic Transformer Layer Dropout (STLD) — paper §3.2.

``H_{l+1} = (1 - d_l) · Block_l(H_l) + d_l · H_l``, ``d_l ~ Bernoulli(P_l)``.

Two execution modes (DESIGN.md §2):

* ``cond``   — paper-faithful: a traced ``lax.cond`` per layer.  One compiled
  graph; at runtime XLA executes only the taken branch, so a dropped layer
  costs neither forward nor backward compute.  Per-batch dynamic, exactly the
  paper's semantics.
* ``gather`` — TPU-native (beyond paper): a *static* active-layer count
  ``k = round(L · (1 - mean_rate))`` with *traced* active indices.  Stacked
  layer params are gathered (``jnp.take``) into a shorter stack and scanned;
  the compiled graph itself has ``k/L`` of the FLOPs and activation footprint.
  Gradients scatter back through the gather, so dropped layers receive exact
  zero updates — numerically identical in expectation to ``cond`` when the
  index distribution matches.

``sample_drops`` draws the paper's independent Bernoulli gates (with a
guaranteed minimum number of active layers); ``sample_active_indices`` draws a
fixed-size active set with inclusion probabilities proportional to
``1 - P_l`` (Gumbel top-k weighted sampling without replacement), the
gather-mode analogue.

Key discipline
--------------
Every sampler here consumes its ``key`` argument *whole* (exactly one
``jax.random`` draw per call) and never splits or folds internally.  Callers
own the stream: the client step does ``rng, kd = jax.random.split(rng)`` per
local step and passes ``kd`` to exactly one sampler, and the cohort engine
fans out one ``jax.random.split(key, n + 1)`` per round so no two devices —
and no two rounds — ever share a key path (regression-tested in
``tests/test_key_discipline.py``).  Passing the same key to two samplers
would correlate their gates; the JXH001 lint rule flags that pattern.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def expected_active_layers(rates) -> jnp.ndarray:
    """E[L-tilde] = sum_l (1 - P_l)   (paper Eq. 4)."""
    return jnp.sum(1.0 - rates)


def _force_min_active(drops, rates, min_active: int):
    """Enforce the active-layer floor: if fewer than ``min_active`` layers
    survive, force-activate the dropped layers with the smallest rates."""
    active = jnp.sum(~drops)
    need = jnp.maximum(min_active - active, 0)
    order = jnp.argsort(jnp.where(drops, rates, jnp.inf))
    rank_of = jnp.argsort(order)
    force = drops & (rank_of < need)
    return drops & ~force


def sample_drops(key, rates, min_active: int = 1):
    """Independent Bernoulli gates d_l (True = dropped), with a floor on the
    number of active layers: if fewer than ``min_active`` layers survive,
    the lowest-rate layers are force-activated."""
    num_layers = rates.shape[0]
    u = jax.random.uniform(key, (num_layers,))
    drops = u < rates
    return _force_min_active(drops, rates, min_active)


def sample_active_indices(key, rates, k: int):
    """Gather-mode: sample k distinct layer indices with probability
    proportional to keep-probability (Gumbel top-k), returned sorted so the
    gathered sub-stack preserves depth order."""
    logits = jnp.log(jnp.clip(1.0 - rates, 1e-6, 1.0))
    g = logits + jax.random.gumbel(key, rates.shape)
    _, idx = jax.lax.top_k(g, k)
    return jnp.sort(idx)


def static_active_count(mean_rate: float, num_layers: int, bucket: int = 1, min_active: int = 1) -> int:
    """Static k for gather mode, rounded up to a bucket to bound recompiles."""
    k = round(num_layers * (1.0 - mean_rate))
    if bucket > 1:
        k = -(-k // bucket) * bucket
    return int(min(num_layers, max(min_active, k)))


def sample_drops_block(key, rates, block_size: int, min_active: int = 1):
    """Structured (LayerDrop-style) variant: contiguous blocks of
    ``block_size`` layers share one Bernoulli gate.  Coarser than the
    paper's per-layer gates but TPU-friendlier in gather mode (gathered
    sub-stacks stay contiguous); used as an ablation."""
    num_layers = rates.shape[0]
    n_blocks = -(-num_layers // block_size)
    # per-block mean rate via one padded reshape-mean (zero-padding keeps
    # block sums exact; divide by the true per-block lengths) instead of a
    # python list of per-slice jnp.mean ops
    padded = jnp.pad(rates, (0, n_blocks * block_size - num_layers))
    counts = jnp.full((n_blocks,), block_size, dtype=rates.dtype).at[-1].set(
        num_layers - (n_blocks - 1) * block_size
    )
    block_rates = padded.reshape(n_blocks, block_size).sum(axis=1) / counts
    block_drops = sample_drops(key, block_rates, min_active=1)
    drops = jnp.repeat(block_drops, block_size)[:num_layers]
    return _force_min_active(drops, rates, min_active)


def gate(block_fn: Callable, drop, h, cache=None):
    """The STLD gate: ``lax.cond(drop, identity, block_fn)``.

    ``block_fn(h, cache) -> (h', aux, cache')``; the identity branch passes
    ``h`` and ``cache`` through with aux = 0, so both branches have identical
    output structure (required by ``lax.cond``) and a skipped layer stores no
    activations for the backward pass — XLA executes only the taken branch.
    """

    def skip_branch(operands):
        h, cache = operands
        return h, jnp.zeros((), dtype=jnp.float32), cache

    def active_branch(operands):
        h, cache = operands
        return block_fn(h, cache)

    return jax.lax.cond(drop, skip_branch, active_branch, (h, cache))
