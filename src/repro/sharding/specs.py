"""Name- and divisibility-driven PartitionSpec rules.

Megatron-style tensor parallel over the ``model`` axis with automatic
fallback: a rule proposes which dim of a weight to shard; if that dim is not
divisible by the model-axis size the engine tries the rule's fallback dims
and finally replicates.  This is what lets one rule set cover all 10
assigned architectures (llama4's 40 heads, whisper's 6 heads, granite's 40
experts / 49155 vocab all hit fallbacks — see DESIGN.md §4).

Conventions:
  * column-parallel (shard output dim):   wq wk wv gate up router embed
  * row-parallel (shard input dim):       wo down out_proj lm_head-ish
  * expert-parallel: leading expert dim of stacked expert weights
  * PEFT params are replicated (tiny; keeps aggregation collective-free)
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


# rule table: ordered-subsequence key-path match -> ordered dim preferences
# to shard on the "model" axis.  First divisible dim wins; rules are tried
# top-to-bottom, so specific rules (channel_mix) precede generic ones (wv).
_RULES = [
    # (path substrings (ordered subsequence), rank, dim preference order)
    (("channel_mix", "wk", "w"), 2, (1,)),
    (("channel_mix", "wv", "w"), 2, (0,)),
    (("experts", "gate"), 3, (0, 2, 1)),
    (("experts", "up"), 3, (0, 2, 1)),
    (("experts", "down"), 3, (0, 1, 2)),
    (("router",), 2, (1,)),
    (("embed",), 2, (0, 1)),
    (("lm_head",), 2, (1, 0)),
    (("pos_embed",), 2, (1,)),
    (("wq", "w"), 2, (1, 0)),
    (("wk", "w"), 2, (1,)),
    (("wv", "w"), 2, (1,)),
    (("wo", "w"), 2, (0, 1)),
    (("gate", "w"), 2, (1,)),
    (("up", "w"), 2, (1,)),
    (("down", "w"), 2, (0,)),
    (("in_proj", "w"), 2, (1,)),
    (("out_proj", "w"), 2, (0,)),
    (("x_proj", "w"), 2, (0,)),
    (("dt_proj", "w"), 2, (1,)),
    (("conv_w",), 2, (1,)),
    (("conv_b",), 1, (0,)),
    (("A_log",), 2, (0,)),
    (("D",), 1, (0,)),
    (("time_mix", "wr", "w"), 2, (1,)),
]


def _path_parts(path) -> tuple:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return tuple(parts)


def _match(parts: tuple, needles: tuple) -> bool:
    it = iter(parts)
    return all(any(n == part for part in it) for n in needles)


def _spec_with_dim(shape, dim: int, tp: int, extra_leading: int = 0):
    dim = dim % len(shape)
    spec = [None] * (len(shape) + extra_leading)
    spec[dim + extra_leading] = "model"
    return P(*spec)


def _stacked_layer_lead(parts: tuple) -> int:
    """1 when the leaf lives under a stacked-native ``layers`` subtree (its
    shapes carry a leading layer axis the per-layer rules must skip), else
    0.  List-layout leaves have an integer index right after ``layers``."""
    for i, p in enumerate(parts):
        if p == "layers":
            nxt = parts[i + 1] if i + 1 < len(parts) else ""
            return 0 if nxt.isdigit() else 1
    return 0


def spec_for_param(path, shape, tp: int, extra_leading: int = 0, expert_shard: str = "auto") -> P:
    """PartitionSpec for one weight leaf.  ``extra_leading`` accounts for a
    stacked layer dim prepended by scan-mode stacking; a stacked-native
    ``layers`` subtree (leading layer axis already present in ``shape``) is
    detected from the key path and handled the same way.

    ``expert_shard='ff'`` shards stacked expert weights on the within-expert
    dim instead of the expert dim — required by the decode weight-gather
    path, where a per-token ``take`` over an expert-sharded dim would
    all-gather every cold expert (EXPERIMENTS.md §Perf C)."""
    parts = _path_parts(path)
    if any("peft" == p for p in parts):
        return P()
    lead = _stacked_layer_lead(parts)
    if lead:
        inner = _spec_for_inner(parts, shape[lead:], tp, extra_leading, expert_shard)
        inner = tuple(inner) + (None,) * (len(shape) - lead - len(tuple(inner)))
        return P(*((None,) * lead + inner))
    return _spec_for_inner(parts, shape, tp, extra_leading, expert_shard)


def _spec_for_inner(parts, shape, tp: int, extra_leading: int, expert_shard: str) -> P:
    for needles, rank, prefs in _RULES:
        if expert_shard == "ff" and needles[0] == "experts":
            # drop the leading expert-dim preference
            prefs = tuple(d for d in prefs if d != 0) + (0,)
        if len(shape) - extra_leading == rank and _match(parts, needles):
            for dim in prefs:
                if shape[dim + extra_leading] % tp == 0 and shape[dim + extra_leading] >= tp:
                    return _spec_with_dim(shape, dim, tp, extra_leading)
            return P()
    # fallback: biases/norms replicate; big 2D+ weights shard last divisible dim
    if len(shape) - extra_leading >= 2:
        for dim in range(len(shape) - 1, extra_leading - 1, -1):
            if shape[dim] % tp == 0 and shape[dim] >= tp and shape[dim] >= 1024:
                spec = [None] * len(shape)
                spec[dim] = "model"
                return P(*spec)
    return P()


def param_specs(params, tp: int, extra_leading: int = 0, fsdp_axes: tuple = (), expert_shard: str = "auto"):
    """Pytree of PartitionSpecs mirroring ``params``.

    ``fsdp_axes``: data-parallel mesh axes to additionally shard parameters
    over (ZeRO-3 style — legitimate for a frozen PEFT base, which carries no
    optimizer state; GSPMD inserts the per-layer all-gathers).  Applied to
    the first still-unsharded dim of every large leaf that divides the axis
    product.
    """
    n_fsdp = _axes_size(fsdp_axes) if fsdp_axes else 1

    def leaf_spec(path, leaf):
        spec = spec_for_param(path, leaf.shape, tp, extra_leading, expert_shard)
        if n_fsdp <= 1 or leaf.size < 1 << 20:
            return spec
        spec_list = list(spec) + [None] * (len(leaf.shape) - len(spec))
        # never ZeRO-shard the stacked layer axis: lax.scan iterates it, so a
        # data-axis sharding there would reshard the operand every layer —
        # FSDP belongs on a within-weight dim, as in the list layout
        lead = _stacked_layer_lead(_path_parts(path))
        for dim in range(lead, len(leaf.shape)):
            if spec_list[dim] is None and leaf.shape[dim] % n_fsdp == 0 and leaf.shape[dim] >= n_fsdp:
                spec_list[dim] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                break
        return P(*spec_list)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def peft_specs(peft_tree):
    """PEFT params replicate (see module docstring)."""
    return jax.tree.map(lambda _: P(), peft_tree)


def batch_spec(batch_axes: tuple, ndim: int, *, batch_dim: int = 0) -> P:
    spec = [None] * ndim
    spec[batch_dim] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    return P(*spec)


def cache_specs(caches, batch_axes: tuple, tp: int, *, shard_seq_on_data: bool = False):
    """Specs for decode caches.

    Attention caches (B, S, KV, HD): batch over the data axes; KV heads over
    ``model`` when divisible (else head_dim, else replicate).  When B == 1
    (``long_500k``) ``shard_seq_on_data=True`` shards the *sequence* dim over
    the data axes instead (distributed long-context decode, DESIGN.md §7).
    Recurrent states (mamba/rwkv) shard batch + their channel dim.
    """

    def leaf_spec(path, leaf):
        parts = _path_parts(path)
        shape = leaf.shape
        name = parts[-1] if parts else ""
        if name == "pos" or len(shape) == 0:
            return P()
        b_ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        if name in ("k", "v") and len(shape) == 4:
            b, s, kv, hd = shape
            spec = [None, None, None, None]
            if shard_seq_on_data and b == 1:
                spec[1] = b_ax
            elif b % _axes_size(batch_axes) == 0 and b >= _axes_size(batch_axes):
                spec[0] = b_ax
            if kv % tp == 0 and kv >= tp:
                spec[2] = "model"
            elif hd % tp == 0 and hd >= tp:
                spec[3] = "model"
            return P(*spec)
        # recurrent states: (B, ...channels...)
        spec = [None] * len(shape)
        if shape[0] % _axes_size(batch_axes) == 0 and shape[0] >= _axes_size(batch_axes):
            spec[0] = b_ax
        for dim in range(len(shape) - 1, 0, -1):
            if shape[dim] % tp == 0 and shape[dim] >= tp and shape[dim] >= 256:
                spec[dim] = "model"
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, caches)


_MESH_AXES_SIZES = {}


def set_mesh_axis_sizes(mesh):
    """Record axis sizes so spec builders can check divisibility."""
    global _MESH_AXES_SIZES
    _MESH_AXES_SIZES = dict(mesh.shape)


def _axes_size(axes: tuple) -> int:
    n = 1
    for a in axes:
        n *= _MESH_AXES_SIZES.get(a, 1)
    return n


def to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
