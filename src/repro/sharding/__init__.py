from repro.sharding.specs import (
    batch_spec,
    cache_specs,
    param_specs,
    peft_specs,
    to_shardings,
)

__all__ = ["param_specs", "peft_specs", "cache_specs", "batch_spec", "to_shardings"]
