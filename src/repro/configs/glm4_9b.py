"""glm4-9b — dense decoder, RoPE, aggressive GQA (kv=2).

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.  [hf:THUDM/glm-4-9b]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "glm4-9b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151_552,
    rope_theta=10_000.0,
    attention_bias=True,   # glm4 uses qkv bias
    max_seq_len=131_072,
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    rope_theta=10_000.0,
    attention_bias=True,
    max_seq_len=512,
)
