"""internvl2-76b — VLM: InternViT (stub frontend) + InternLM2-76B backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  The vision encoder
and MLP projector are stubbed per the assignment carve-out; ``input_specs``
provides pre-projected patch embeddings.  [arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "internvl2-76b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128_256,
    modality="vision",
    frontend_seq=256,          # 256 patch embeddings per image (448px, pixel-shuffle)
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="vlm",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    modality="vision",
    frontend_seq=16,
    rope_theta=1_000_000.0,
    max_seq_len=512,
)
