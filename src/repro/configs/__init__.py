"""Architecture config registry.

``get_config(arch_id)`` returns the FULL assigned configuration;
``get_config(arch_id, smoke=True)`` returns the reduced same-family variant
used by CPU smoke tests (2 layers, d_model<=512, <=4 experts).
"""
from repro.configs import (
    glm4_9b,
    granite_moe_3b_a800m,
    h2o_danube_1_8b,
    internvl2_76b,
    jamba_v0_1_52b,
    llama4_scout_17b_a16e,
    qwen3_1_7b,
    rwkv6_3b,
    whisper_tiny,
    yi_6b,
)
from repro.configs.base import (
    INPUT_SHAPES,
    FederatedConfig,
    InputShape,
    MambaConfig,
    ModelConfig,
    PEFTConfig,
    RunConfig,
    RWKVConfig,
    STLDConfig,
    TrainConfig,
)

_MODULES = (
    jamba_v0_1_52b,
    llama4_scout_17b_a16e,
    internvl2_76b,
    yi_6b,
    granite_moe_3b_a800m,
    rwkv6_3b,
    glm4_9b,
    qwen3_1_7b,
    h2o_danube_1_8b,
    whisper_tiny,
)

ARCH_IDS = tuple(m.ARCH_ID for m in _MODULES)
_BY_ID = {m.ARCH_ID: m for m in _MODULES}

# (arch, shape) pairs excluded from long-context decode, with reasons
# (DESIGN.md §5).  Everything else in ARCH_IDS x INPUT_SHAPES runs.
LONG_CONTEXT_SKIPS = {
    "llama4-scout-17b-a16e": "full global attention (chunked-RoPE variant not implemented)",
    "internvl2-76b": "full attention",
    "yi-6b": "full attention",
    "glm4-9b": "full attention",
    "qwen3-1.7b": "full attention",
    "granite-moe-3b-a800m": "full attention",
    "whisper-tiny": "full attention; decoder context out-of-family at 500k",
}


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in _BY_ID:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_BY_ID)}")
    mod = _BY_ID[arch_id]
    return mod.SMOKE if smoke else mod.FULL


def shape_applicable(arch_id: str, shape_name: str) -> bool:
    """Whether an (arch, input-shape) cell runs (DESIGN.md skip matrix)."""
    if shape_name == "long_500k" and arch_id in LONG_CONTEXT_SKIPS:
        return False
    return True


__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "LONG_CONTEXT_SKIPS",
    "FederatedConfig",
    "InputShape",
    "MambaConfig",
    "ModelConfig",
    "PEFTConfig",
    "RunConfig",
    "RWKVConfig",
    "STLDConfig",
    "TrainConfig",
    "get_config",
    "shape_applicable",
]
