"""Configuration system for repro.

Every assigned architecture is described by a single :class:`ModelConfig`
dataclass.  Configs are plain data — no jax imports — so they can be loaded
by launchers before device initialisation (important for the dry-run, which
must set XLA_FLAGS before jax is touched).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MambaConfig:
    """Selective-SSM (Mamba) block hyper-parameters (used by hybrid archs)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank > 0 else max(1, -(-d_model // 16))


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 ("Finch") block hyper-parameters."""

    head_dim: int = 64
    decay_lora_dim: int = 64
    gate_lora_dim: int = 128
    token_shift_lora_dim: int = 32


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` is one of ``dense | moe | hybrid | ssm | vlm | audio``.
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # -- attention ---------------------------------------------------------
    head_dim: int = 0  # 0 -> d_model // num_heads
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # tokens; None = global attention
    rope_theta: float = 10_000.0
    attention_bias: bool = False

    # -- MoE ---------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # a layer l hosts MoE iff l % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    shared_expert: bool = False  # granite-style shared dense path alongside MoE
    moe_dispatch: str = "einsum"  # einsum (GShard one-hot) | gather (permutation)

    # -- hybrid (Jamba) ----------------------------------------------------
    attn_every: int = 0         # 0 = every layer is attention (pure transformer)
    attn_offset: int = 0        # jamba: attention at l % attn_every == attn_offset
    mamba: Optional[MambaConfig] = None

    # -- SSM (RWKV) --------------------------------------------------------
    rwkv: Optional[RWKVConfig] = None

    # -- encoder/decoder + modality frontends ------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    modality: str = "text"      # text | audio | vision
    frontend_seq: int = 0       # frames (audio) / patches (vision) provided by stub

    # -- misc ---------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 8192
    activation: str = "silu"    # silu (SwiGLU) | gelu (plain MLP, whisper)
    dtype: str = "bfloat16"     # activation/compute dtype
    param_dtype: str = "float32"

    # ------------------------------------------------------------------ api
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim > 0 else self.d_model // self.num_heads

    def is_attention_layer(self, l: int) -> bool:
        if self.attn_every <= 0:
            return True
        return l % self.attn_every == self.attn_offset

    def is_moe_layer(self, l: int) -> bool:
        if self.num_experts <= 0:
            return False
        return l % self.moe_every == self.moe_offset

    @property
    def layer_period(self) -> int:
        """Smallest period after which the layer pattern repeats."""
        p = 1
        if self.attn_every > 0:
            p = _lcm(p, self.attn_every)
        if self.num_experts > 0:
            p = _lcm(p, self.moe_every)
        return p

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter counting (for roofline MODEL_FLOPS and the system model) ----
    def param_counts(self) -> dict:
        """Analytic parameter counts: total, active (MoE top-k), embedding."""
        d, hd = self.d_model, self.resolved_head_dim
        h, kv, ff = self.num_heads, self.num_kv_heads, self.d_ff
        attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        if self.activation == "silu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        norms = 2 * d

        mamba_p = 0
        if self.mamba is not None:
            m = self.mamba
            d_in = m.expand * d
            dtr = m.resolved_dt_rank(d)
            mamba_p = (
                d * 2 * d_in            # in_proj
                + d_in * m.d_conv       # conv
                + d_in * (dtr + 2 * m.d_state)  # x_proj
                + dtr * d_in            # dt_proj
                + d_in * m.d_state      # A_log
                + d_in                  # D
                + d_in * d              # out_proj
            )
        rwkv_p = 0
        if self.rwkv is not None:
            r = self.rwkv
            rwkv_p = (
                4 * d * d               # r,k,v,output (time-mix)
                + d * r.gate_lora_dim + r.gate_lora_dim * d
                + d * r.decay_lora_dim + r.decay_lora_dim * d
                + 2 * (d * r.token_shift_lora_dim * 5)
                + (d * ff + ff * d + d * d)  # channel mix: key/value/receptance
            )

        total = 0
        active = 0
        for l in range(self.num_layers):
            if self.family == "ssm":
                layer_tot = rwkv_p + norms
                layer_act = layer_tot
            elif self.is_attention_layer(l):
                layer_tot = attn + norms
                layer_act = attn + norms
            else:
                layer_tot = mamba_p + norms
                layer_act = layer_tot
            if self.family != "ssm":
                if self.is_moe_layer(l):
                    layer_tot += self.num_experts * mlp + d * self.num_experts
                    layer_act += max(self.top_k, 1) * mlp + d * self.num_experts
                    if self.shared_expert:
                        layer_tot += mlp
                        layer_act += mlp
                else:
                    layer_tot += mlp
                    layer_act += mlp
            total += layer_tot
            active += layer_act

        emb = self.vocab_size * d
        total += emb + d + (0 if self.tie_embeddings else emb)
        active += emb + d + (0 if self.tie_embeddings else emb)
        if self.is_encoder_decoder:
            enc_layer = attn + mlp + norms
            total += self.num_encoder_layers * enc_layer
            active += self.num_encoder_layers * enc_layer
            # decoder cross-attention
            total += self.num_layers * attn
            active += self.num_layers * attn
        return {"total": total, "active": active, "embedding": emb}


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class PEFTConfig:
    """Parameter-efficient fine-tuning configuration (paper §2.2)."""

    method: str = "lora"        # lora | adapter | bitfit | none
    lora_rank: int = 8
    lora_alpha: float = 16.0
    lora_targets: tuple = ("q", "v")  # which projections get LoRA
    adapter_dim: int = 64


@dataclass(frozen=True)
class STLDConfig:
    """Stochastic transformer layer dropout configuration (paper §3.2-3.3)."""

    enabled: bool = True
    mode: str = "cond"            # cond (paper-faithful) | gather (TPU-native)
    distribution: str = "incremental"  # uniform | decay | incremental | normal
    mean_rate: float = 0.5
    normal_std: float = 0.1
    min_active_layers: int = 1
    # gather-mode: static active count = round(L * (1 - mean_rate)), bucketed
    gather_bucket: int = 4


@dataclass(frozen=True)
class FederatedConfig:
    """Federated fine-tuning round configuration (paper §6.1)."""

    num_devices: int = 100
    devices_per_round: int = 10
    local_epochs: int = 1
    local_steps: int = 4
    batch_size: int = 16
    rounds: int = 100
    dirichlet_alpha: float = 1.0
    target_accuracy: float = 0.9
    # PTLS
    ptls_enabled: bool = True
    ptls_share_fraction: float = 0.5  # k = fraction * L layers shared
    # bandit configurator
    configurator_enabled: bool = True
    explore_rate: float = 0.3
    explore_interval: int = 5
    num_candidates: int = 4
    window_size: int = 8
    rate_grid: tuple = (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)
    seed: int = 0


@dataclass(frozen=True)
class TrainConfig:
    """Optimizer / schedule."""

    learning_rate: float = 2e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 20
    schedule: str = "cosine"  # cosine | linear | constant
    total_steps: int = 1000


@dataclass(frozen=True)
class RunConfig:
    """Top-level bundle handed to launchers."""

    model: ModelConfig
    peft: PEFTConfig = field(default_factory=PEFTConfig)
    stld: STLDConfig = field(default_factory=STLDConfig)
    federated: FederatedConfig = field(default_factory=FederatedConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
