"""rwkv6-3b ("Finch") — attention-free RNN with data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536.  [arXiv:2404.05892]
"""
from repro.configs.base import ModelConfig, RWKVConfig

ARCH_ID = "rwkv6-3b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,            # 2560 / head_dim 64
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, decay_lora_dim=64, gate_lora_dim=160),
    max_seq_len=524_288,     # O(1) state: unbounded context
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="ssm",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    rwkv=RWKVConfig(head_dim=32, decay_lora_dim=16, gate_lora_dim=32),
    max_seq_len=512,
)
