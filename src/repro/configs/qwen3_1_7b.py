"""qwen3-1.7b — dense decoder with qk-norm and GQA.

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.  [hf:Qwen/Qwen3-8B]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-1.7b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    max_seq_len=32_768,
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    max_seq_len=512,
)
