"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave with MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Attention at layer l % 8 == 4 (1 attention : 7 mamba), MoE every other
layer.  [arXiv:2403.19887]
"""
from repro.configs.base import MambaConfig, ModelConfig

ARCH_ID = "jamba-v0.1-52b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    rope_theta=10_000.0,  # jamba uses no positional embeddings in attn; keep rope off
    max_seq_len=524_288,
)
# Jamba attention layers use no RoPE (Mamba provides position); model honors
# rope_theta<=0 as "no rotary".
FULL = FULL.replace(rope_theta=0.0)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="hybrid",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    num_experts=4,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=2,
    attn_offset=1,
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
    rope_theta=0.0,
    max_seq_len=512,
)
