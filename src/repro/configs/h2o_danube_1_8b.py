"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
[arXiv:2401.16818]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "h2o-danube-1.8b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32_000,
    sliding_window=4096,
    rope_theta=10_000.0,
    max_seq_len=524_288,   # SWA -> O(window) decode cache; long-context capable
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    sliding_window=64,
    rope_theta=10_000.0,
    max_seq_len=512,
)
