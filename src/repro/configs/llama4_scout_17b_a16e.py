"""llama4-scout-17b-a16e — MoE decoder, early fusion (text backbone here).

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "llama4-scout-17b-a16e"

FULL = ModelConfig(
    name=ARCH_ID,
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    num_experts=16,
    top_k=1,
    moe_every=1,
    shared_expert=True,   # llama4 routes top-1 + always-on shared expert
    rope_theta=500_000.0,
    max_seq_len=131_072,
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    num_experts=4,
    top_k=1,
    moe_every=1,
    shared_expert=True,
    rope_theta=500_000.0,
    max_seq_len=512,
)
