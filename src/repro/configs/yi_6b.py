"""yi-6b — dense llama-arch GQA decoder.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.  [arXiv:2403.04652]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "yi-6b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64_000,
    rope_theta=5_000_000.0,
    max_seq_len=32_768,
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    rope_theta=5_000_000.0,
    max_seq_len=512,
)
