"""whisper-tiny — encoder-decoder audio model (conv frontend stubbed).

4L d_model=384 6H d_ff=1536 vocab=51865; encoder consumes 1500 frame
embeddings (mel+conv stub), decoder is causal with cross-attention.
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "whisper-tiny"

FULL = ModelConfig(
    name=ARCH_ID,
    family="audio",
    num_layers=4,              # decoder layers
    num_encoder_layers=4,
    is_encoder_decoder=True,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    modality="audio",
    frontend_seq=1500,         # 30 s audio -> 1500 frames after conv stub
    activation="gelu",
    rope_theta=0.0,            # whisper uses learned/sinusoidal positions
    max_seq_len=32_768,
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="audio",
    num_layers=2,
    num_encoder_layers=2,
    is_encoder_decoder=True,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    modality="audio",
    frontend_seq=64,
    activation="gelu",
    rope_theta=0.0,
    max_seq_len=512,
)
