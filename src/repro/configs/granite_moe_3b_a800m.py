"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8, tiny experts.

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "granite-moe-3b-a800m"

FULL = ModelConfig(
    name=ARCH_ID,
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    num_experts=40,
    top_k=8,
    moe_every=1,
    rope_theta=10_000.0,
    max_seq_len=4096,
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="moe",
    num_layers=2,
    d_model=96,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    vocab_size=512,
    num_experts=4,
    top_k=2,
    moe_every=1,
    rope_theta=10_000.0,
    max_seq_len=512,
)
