"""Mixture-of-Experts with GShard-style capacity-based dense dispatch.

Token groups keep the dispatch tensors bounded: tokens (B, S, d) are
reshaped to (G, group, d); per group a top-k router builds dispatch /
combine tensors (group, E, C).  The einsum formulation is TPU-native: all
work is MXU matmuls, and with experts sharded over the ``model`` mesh axis
GSPMD lowers dispatch/combine into all-to-all style collectives.

Aux loss is the standard load-balance loss: ``E * sum_e f_e * p_e``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.initializers import truncated_lecun
from repro.nn.mlp import init_mlp, mlp_apply

_DEFAULT_GROUP = 4096
# below this many tokens (single-request decode), dispatch by gathering the
# routed experts' WEIGHTS instead of routing tokens through all E experts:
# cuts both the E/topk FLOP waste and — critically for decode, which is
# weight-read bound — the HBM traffic of cold experts' weights.
_WEIGHT_GATHER_MAX_TOKENS = 8


def init_moe(key, cfg):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, ke, ks = jax.random.split(key, 3)
    ekeys = jax.random.split(ke, e)
    experts = [init_mlp(k, cfg) for k in ekeys]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *experts)
    p = {"router": {"w": truncated_lecun(kr, (d, e))}, "experts": stacked}
    if cfg.shared_expert:
        p["shared"] = init_mlp(ks, cfg)
    return p


def _expert_ffn(experts, cfg, x):
    """x: (E, C, d) -> (E, C, d) with per-expert stacked weights."""
    if "gate" in experts:
        g = jnp.einsum("ecd,edf->ecf", x, experts["gate"]["w"].astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", x, experts["up"]["w"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, experts["up"]["w"].astype(x.dtype)))
        if "b" in experts["up"]:
            h = h + experts["up"]["b"].astype(x.dtype)[:, None, :]
    y = jnp.einsum("ecf,efd->ecd", h, experts["down"]["w"].astype(x.dtype))
    if "b" in experts["down"]:
        y = y + experts["down"]["b"].astype(x.dtype)[:, None, :]
    return y


def moe_apply(params, cfg, x, group_size: Optional[int] = None, dispatch_mode: Optional[str] = None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    ``dispatch_mode``:
      * ``einsum`` — GShard one-hot matmul dispatch/combine (baseline).
        Costs ~2*T*E*C*d extra MXU FLOPs (dispatch + combine).
      * ``gather`` — beyond-paper: build the (E, C) token-index table with
        argsort/cumsum logic and move tokens with take/segment-scatter;
        the permutation costs bytes, not FLOPs (EXPERIMENTS.md §Perf).
    """
    dispatch_mode = dispatch_mode or cfg.moe_dispatch
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    if t <= _WEIGHT_GATHER_MAX_TOKENS and dispatch_mode != "einsum_forced":
        return _moe_weight_gather(params, cfg, x)
    g = group_size or min(t, _DEFAULT_GROUP)
    if t % g:
        g = t  # fall back to a single group for odd token counts (smoke tests)
    n_groups = t // g
    xg = tokens.reshape(n_groups, g, d)

    cap = int(max(k, g / e * cfg.capacity_factor * k))
    cap = min(cap, g)

    logits = jnp.einsum(
        "gtd,de->gte", xg, params["router"]["w"].astype(x.dtype)
    ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, g, E)

    # top-k routing: iteratively take argmax, mask, renormalise over chosen.
    gates = []
    masks = []
    remaining = probs
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)  # (G, g)
        onehot = jax.nn.one_hot(idx, e, dtype=probs.dtype)
        gates.append(jnp.sum(probs * onehot, axis=-1))  # (G, g)
        masks.append(onehot)
        remaining = remaining * (1.0 - onehot)
    gate_stack = jnp.stack(gates, axis=-1)  # (G, g, k)
    denom = jnp.sum(gate_stack, axis=-1, keepdims=True) + 1e-9
    gate_stack = gate_stack / denom

    # load-balance aux loss over the *first* choice (Switch convention).
    # NOTE: minimized at 1 only in expectation / when frac_tokens aligns
    # with mean_probs (Jensen); over a finite token sample the first-choice
    # counts can anti-correlate with the mean probs and dip slightly below 1.
    frac_tokens = jnp.mean(masks[0], axis=1)          # (G, E)
    mean_probs = jnp.mean(probs, axis=1)              # (G, E)
    aux = e * jnp.mean(jnp.sum(frac_tokens * mean_probs, axis=-1))

    # capacity assignment: position of each token within its expert queue.
    used = jnp.zeros((n_groups, e), dtype=jnp.int32)
    choice_expert, choice_pos, choice_keep = [], [], []
    onehots = []
    for i in range(k):
        mask_i = masks[i]                              # (G, g, E)
        pos_in_e = jnp.cumsum(mask_i, axis=1) - mask_i + used[:, None, :]
        keep = (pos_in_e < cap) * mask_i               # drop overflow tokens
        choice_expert.append(jnp.argmax(mask_i, axis=-1).astype(jnp.int32))     # (G, g)
        choice_pos.append(jnp.sum(pos_in_e * mask_i, axis=-1).astype(jnp.int32))
        choice_keep.append(jnp.sum(keep, axis=-1))                              # (G, g)
        onehots.append((pos_in_e, keep))
        used = used + jnp.sum(keep, axis=1).astype(jnp.int32)

    if dispatch_mode in ("einsum", "einsum_forced"):
        dispatch = jnp.zeros((n_groups, g, e, cap), dtype=x.dtype)
        combine = jnp.zeros((n_groups, g, e, cap), dtype=x.dtype)
        for i in range(k):
            pos_in_e, keep = onehots[i]
            onehot_cap = jax.nn.one_hot(pos_in_e, cap, dtype=x.dtype) * keep.astype(x.dtype)[..., None]
            dispatch = dispatch + onehot_cap
            combine = combine + onehot_cap * gate_stack[..., i].astype(x.dtype)[..., None, None]
        expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)   # (G, E, C, d)
        # fold groups into the per-expert token dim so expert FFNs are single
        # large matmuls: (E, G*C, d)
        ein = expert_in.transpose(1, 0, 2, 3).reshape(e, n_groups * cap, d)
        eout = _expert_ffn(params["experts"], cfg, ein)
        eout = eout.reshape(e, n_groups, cap, d).transpose(1, 0, 2, 3)
        out = jnp.einsum("gtec,gecd->gtd", combine, eout)         # (G, g, d)
        out = out.reshape(b, s, d)
    elif dispatch_mode == "gather":
        # permutation-based dispatch: bytes instead of one-hot matmul FLOPs
        n_slots = e * cap
        xg_pad = jnp.concatenate([xg, jnp.zeros((n_groups, 1, d), xg.dtype)], axis=1)
        table = jnp.full((n_groups, n_slots), g, dtype=jnp.int32)  # g -> zero row
        tok_ids = jnp.broadcast_to(jnp.arange(g, dtype=jnp.int32), (n_groups, g))
        for i in range(k):
            slot = choice_expert[i] * cap + choice_pos[i]          # (G, g)
            slot = jnp.where(choice_keep[i] > 0, slot, n_slots)    # park overflow
            table = jax.vmap(
                lambda t, s_, ids: t.at[s_].set(ids, mode="drop")
            )(table, slot, tok_ids)
        expert_in = jnp.take_along_axis(xg_pad, table[..., None], axis=1)  # (G, E*C, d)
        ein = expert_in.reshape(n_groups, e, cap, d).transpose(1, 0, 2, 3).reshape(
            e, n_groups * cap, d
        )
        eout = _expert_ffn(params["experts"], cfg, ein)
        eout = eout.reshape(e, n_groups, cap, d).transpose(1, 0, 2, 3)  # (G,E,C,d)
        eout_flat = eout.reshape(n_groups, n_slots, d)
        eout_pad = jnp.concatenate(
            [eout_flat, jnp.zeros((n_groups, 1, d), eout_flat.dtype)], axis=1
        )
        out = jnp.zeros((n_groups, g, d), dtype=x.dtype)
        for i in range(k):
            slot = choice_expert[i] * cap + choice_pos[i]
            slot = jnp.where(choice_keep[i] > 0, slot, n_slots)    # -> zero row
            picked = jnp.take_along_axis(eout_pad, slot[..., None], axis=1)
            out = out + gate_stack[..., i].astype(x.dtype)[..., None] * picked
        out = out.reshape(b, s, d)
    else:
        raise ValueError(f"unknown dispatch_mode {dispatch_mode!r}")

    if "shared" in params:
        out = out + mlp_apply(params["shared"], cfg, x)
    return out, aux


def _moe_weight_gather(params, cfg, x):
    """Decode-path MoE: per-token top-k expert WEIGHT gather.

    x: (B, S, d) with B*S small.  FLOPs = exactly topk FFNs per token; HBM
    traffic = only the routed experts' weights (vLLM-style decode MoE).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    logits = (xt @ params["router"]["w"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)          # (t, E)
    top_p, top_idx = jax.lax.top_k(probs, k)         # (t, k)
    gates = (top_p / (jnp.sum(top_p, -1, keepdims=True) + 1e-9)).astype(x.dtype)

    ew = params["experts"]
    out = jnp.zeros_like(xt)
    for i in range(k):  # k is small (<=8); unrolled gathers stay tiny
        idx = top_idx[:, i]                          # (t,)
        if "gate" in ew:
            gw = jnp.take(ew["gate"]["w"], idx, axis=0).astype(x.dtype)  # (t,d,ff)
            uw = jnp.take(ew["up"]["w"], idx, axis=0).astype(x.dtype)
            h = jax.nn.silu(jnp.einsum("td,tdf->tf", xt, gw)) * jnp.einsum(
                "td,tdf->tf", xt, uw
            )
        else:
            uw = jnp.take(ew["up"]["w"], idx, axis=0).astype(x.dtype)
            h = jax.nn.gelu(jnp.einsum("td,tdf->tf", xt, uw))
            if "b" in ew["up"]:
                h = h + jnp.take(ew["up"]["b"], idx, axis=0).astype(x.dtype)
        dw = jnp.take(ew["down"]["w"], idx, axis=0).astype(x.dtype)
        y = jnp.einsum("tf,tfd->td", h, dw)
        if "b" in ew["down"]:
            y = y + jnp.take(ew["down"]["b"], idx, axis=0).astype(x.dtype)
        out = out + gates[:, i][:, None] * y

    # aux loss is a training-time quantity; decode returns 0
    aux = jnp.zeros((), dtype=jnp.float32)
    out = out.reshape(b, s, d)
    if "shared" in params:
        out = out + mlp_apply(params["shared"], cfg, x)
    return out, aux
