"""Rotary position embeddings (RoPE), theta-configurable.

``theta <= 0`` disables rotary (Jamba attention layers, whisper).
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # (head_dim/2,)


def apply_rotary(x, positions, theta: float):
    """x: (..., S, H, head_dim); positions: broadcastable to (..., S)."""
    if theta is None or theta <= 0:
        return x
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                      # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
