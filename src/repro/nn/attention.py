"""GQA attention: causal / bidirectional / sliding-window, KV-cache decode.

Two execution paths:

* ``naive`` — full (Sq, Skv) score matrix.  Used when the score tensor is
  small enough; FLOP-exact for ``cost_analysis``.
* ``q-blocked`` — python loop over query blocks (NOT ``lax.scan``) so the
  dry-run's ``cost_analysis`` still counts every block.  Bounds the transient
  score tensor for 32k-prefill shapes.

The Pallas flash-attention kernel (``repro.kernels.flash_attention``) is the
TPU production path, selected with ``attention_impl='pallas'``; the XLA paths
here are the portable reference used for CPU smoke tests and dry-run
lowering (Pallas does not lower to the CPU backend).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.linear import apply_linear, init_linear
from repro.nn.norms import apply_rmsnorm, init_rmsnorm
from repro.nn.rotary import apply_rotary

NEG_INF = -1e30
# largest Sq*Skv score tile (per head, per batch element) before q-blocking
_MAX_NAIVE_SCORES = 8192 * 8192


def init_attention(key, cfg):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": init_linear(kq, d, cfg.num_heads * hd, bias=cfg.attention_bias),
        "wk": init_linear(kk, d, cfg.num_kv_heads * hd, bias=cfg.attention_bias),
        "wv": init_linear(kv, d, cfg.num_kv_heads * hd, bias=cfg.attention_bias),
        "wo": init_linear(ko, cfg.num_heads * hd, d, bias=False),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """Additive mask bias from absolute positions.

    1D positions give (Sq, Skv); batched 2D positions — (B, Sq) / (B, Skv),
    the serving path where every row decodes at its own depth — give
    (B, Sq, Skv).
    """
    qe, ke = q_pos[..., :, None], k_pos[..., None, :]
    shape = jnp.broadcast_shapes(qe.shape, ke.shape)
    ok = jnp.broadcast_to(jnp.asarray(True), shape)
    if causal:
        ok = ok & (ke <= qe)
    if window is not None:
        ok = ok & (ke > qe - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias):
    """Grouped-GQA attention without materialising repeated KV heads.

    q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd); bias: (Sq,Skv) additive fp32, or
    (B,Sq,Skv) for per-row masks (batched serving decode).
    The einsum carries a (kv-group, repeat) split of the query heads, so the
    KV tensors are contracted directly — no (B,S,KV,rep,hd) broadcast copy
    (which GSPMD could not reshard efficiently for head_dim-sharded caches).
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    qg = q.reshape(b, sq, kv, rep, hd)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32)
    bias = bias[:, None, None] if bias.ndim == 3 else bias[None, None, None]
    scores = scores * (hd**-0.5) + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)
    return out.reshape(b, sq, h, hd)


def multi_head_attention(
    q,
    k,
    v,
    *,
    q_positions,
    k_positions,
    causal: bool = True,
    window: Optional[int] = None,
):
    """Blocked-or-naive masked attention.  q: (B,S,H,hd); k,v: (B,S,KV,hd).

    Batched (B, S) positions take the naive path only — serving decode is
    one query token per row, so the score tile is always small.
    """
    sq, skv = q.shape[1], k.shape[1]
    if jnp.ndim(q_positions) == 2:
        bias = _mask_bias(q_positions, k_positions, causal, window)
        return _sdpa(q, k, v, bias)
    if sq * skv <= _MAX_NAIVE_SCORES or sq < 2:
        bias = _mask_bias(q_positions, k_positions, causal, window)
        return _sdpa(q, k, v, bias)
    # q-blocked path: python loop keeps cost_analysis exact (no scan).
    block = max(1, _MAX_NAIVE_SCORES // skv)
    block = min(block, sq)
    outs = []
    for start in range(0, sq, block):
        stop = min(start + block, sq)
        bias = _mask_bias(q_positions[start:stop], k_positions, causal, window)
        outs.append(_sdpa(q[:, start:stop], k, v, bias))
    return jnp.concatenate(outs, axis=1)


def attention_apply(
    params,
    cfg,
    x,
    positions,
    *,
    causal: bool = True,
    cache: Optional[dict] = None,
    peft: Optional[dict] = None,
    lora_scale: float = 1.0,
):
    """Self-attention over ``x`` (B, S, d).

    ``cache``: ``{"k": (B, S_max, kv, hd), "v": ..., "pos": ()}``; when given,
    S is the number of new tokens (1 for decode) written at ``cache["pos"]``
    and attention runs against the whole cache.  Returns (out, new_cache).
    """
    peft = peft or {}
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads

    q = apply_linear(params["wq"], x, peft.get("q"), lora_scale).reshape(b, s, h, hd)
    k = apply_linear(params["wk"], x, peft.get("k"), lora_scale).reshape(b, s, kvh, hd)
    v = apply_linear(params["wv"], x, peft.get("v"), lora_scale).reshape(b, s, kvh, hd)

    if cfg.qk_norm:
        q = apply_rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = apply_rmsnorm(params["k_norm"], k, cfg.norm_eps)

    q = apply_rotary(q, positions, cfg.rope_theta)
    k = apply_rotary(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # Ring-buffer cache: for sliding-window archs the cache holds only
        # ``window`` slots and writes wrap modulo the cache length.  For
        # global-attention archs cache_len == max_len and the modulo is a
        # no-op.  Multi-token writes (prefill) assume no wrap within the
        # write (pos + s <= cache_len).
        pos = cache["pos"]
        cache_len = cache["k"].shape[1]
        if jnp.ndim(pos) == 1:
            # Batched serving cache: one write position per row, because
            # continuous batching runs rows at different sequence depths.
            # Token-level admission keeps this to one new token per row.
            if s != 1:
                raise ValueError(
                    "batched KV cache (per-row positions) decodes one token "
                    f"per row per step, got S={s}"
                )
            rows = jnp.arange(b)
            write_pos = pos % cache_len
            ck = cache["k"].at[rows, write_pos].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, write_pos].set(v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv, "pos": pos + s}
            k_full, v_full = ck.astype(x.dtype), cv.astype(x.dtype)
            last_pos = pos + s - 1  # (B,)
            slots = jnp.arange(cache_len)
            k_positions = last_pos[:, None] - jnp.mod(
                last_pos[:, None] - slots[None, :], cache_len
            )
            # Negative = slot not yet written *by this request*: a recycled
            # row still holds the previous tenant's K/V in the ring, and the
            # position mask keeps it inert without a cache clear.
            k_positions = jnp.where(
                k_positions < 0, jnp.iinfo(jnp.int32).max, k_positions
            )
            out = multi_head_attention(
                q,
                k_full,
                v_full,
                q_positions=positions,
                k_positions=k_positions,
                causal=True,
                window=cfg.sliding_window,
            )
            out = out.reshape(b, s, h * hd)
            out = apply_linear(params["wo"], out, peft.get("o"), lora_scale)
            return out, new_cache
        if s >= cache_len:
            # Prefill longer than the ring (SWA window): attention runs over
            # the full in-sequence K/V (early queries need keys the ring
            # discards); the ring then keeps only the last cache_len tokens,
            # rolled so absolute position p lands in slot p % cache_len.
            shift = (s % cache_len) if s > cache_len else 0
            ck = jnp.roll(k[:, -cache_len:].astype(cache["k"].dtype), shift, axis=1)
            cv = jnp.roll(v[:, -cache_len:].astype(cache["v"].dtype), shift, axis=1)
            new_cache = {"k": ck, "v": cv, "pos": pos + s}
            out = multi_head_attention(
                q, k, v,
                q_positions=positions,
                k_positions=positions,
                causal=True,
                window=cfg.sliding_window,
            )
            out = out.reshape(b, s, h * hd)
            out = apply_linear(params["wo"], out, peft.get("o"), lora_scale)
            return out, new_cache
        else:
            write_pos = pos % cache_len
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, write_pos, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, write_pos, 0, 0)
            )
        new_cache = {"k": ck, "v": cv, "pos": pos + s}
        k_full, v_full = ck.astype(x.dtype), cv.astype(x.dtype)
        # absolute position held by ring slot i: the unique p <= last_pos
        # with p == i (mod cache_len) and p > last_pos - cache_len.
        last_pos = pos + s - 1
        slots = jnp.arange(cache_len)
        k_positions = last_pos - jnp.mod(last_pos - slots, cache_len)
        # slots never written (cold start) sit at negative positions only when
        # last_pos < cache_len; causality masks them since q >= 0 > p is false
        # -- mask them explicitly instead:
        k_positions = jnp.where(k_positions < 0, jnp.iinfo(jnp.int32).max, k_positions)
        out = multi_head_attention(
            q,
            k_full,
            v_full,
            q_positions=positions,
            k_positions=k_positions,
            causal=True,
            window=cfg.sliding_window,
        )
    else:
        out = multi_head_attention(
            q,
            k,
            v,
            q_positions=positions,
            k_positions=positions,
            causal=causal,
            window=cfg.sliding_window,
        )

    out = out.reshape(b, s, h * hd)
    out = apply_linear(params["wo"], out, peft.get("o"), lora_scale)
    return out, new_cache


def init_cross_attention(key, cfg):
    """Cross-attention (whisper decoder): q from decoder, kv from encoder."""
    return init_attention(key, cfg)


def cross_attention_apply(
    params,
    cfg,
    x,
    enc_kv,
    *,
    peft: Optional[dict] = None,
    lora_scale: float = 1.0,
):
    """``enc_kv``: precomputed {"k","v"} (B, S_enc, kv, hd) from encoder out."""
    peft = peft or {}
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = apply_linear(params["wq"], x, peft.get("q"), lora_scale).reshape(
        b, s, cfg.num_heads, hd
    )
    out = multi_head_attention(
        q,
        enc_kv["k"].astype(x.dtype),
        enc_kv["v"].astype(x.dtype),
        q_positions=jnp.arange(s),
        k_positions=jnp.arange(enc_kv["k"].shape[1]),
        causal=False,
        window=None,
    )
    out = out.reshape(b, s, cfg.num_heads * hd)
    return apply_linear(params["wo"], out, peft.get("o"), lora_scale)


def encode_cross_kv(params, cfg, enc_out):
    """Precompute encoder K/V once per sequence (whisper serving hot path)."""
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = apply_linear(params["wk"], enc_out).reshape(b, s, cfg.num_kv_heads, hd)
    v = apply_linear(params["wv"], enc_out).reshape(b, s, cfg.num_kv_heads, hd)
    return {"k": k, "v": v}
