"""Parameter initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(key, shape, stddev: float = 0.02, dtype=jnp.float32):
    return stddev * jax.random.normal(key, shape, dtype=dtype)


def truncated_lecun(key, shape, dtype=jnp.float32):
    """LeCun-normal (fan-in) truncated init, the default for projections."""
    fan_in = shape[0] if len(shape) >= 1 else 1
    stddev = (1.0 / max(1, fan_in)) ** 0.5
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype=dtype)


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype=dtype)
