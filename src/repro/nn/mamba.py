"""Mamba (selective SSM) block — Jamba's recurrent layer.

Training/prefill uses the *parallel* form: input-dependent (Delta, B, C) are
computed with dense matmuls, then the diagonal recurrence
``h_t = a_t * h_{t-1} + b_t`` runs as a ``jax.lax.associative_scan`` (log-depth,
unrolled — FLOP-visible to cost_analysis, MXU/VPU friendly on TPU).

Decode uses the O(1) sequential step with a carried ``{"conv", "ssm"}`` state.

TPU adaptation (DESIGN.md §2): the CUDA selective-scan kernel keeps h in
shared memory; here the associative scan materialises (B, S, d_in, N)
transients, which we bound by sharding ``d_in`` over the ``model`` mesh axis
(the recurrence is elementwise in d_in, so this is communication-free) and by
rematerialisation in the training step.  The Pallas kernel
(``repro.kernels.mamba_scan``) is the chunked VMEM-resident production path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.initializers import truncated_lecun
from repro.nn.linear import apply_linear, init_linear


def init_mamba(key, cfg):
    m = cfg.mamba
    d = cfg.d_model
    d_in = m.expand * d
    dtr = m.resolved_dt_rank(d)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    a = jnp.broadcast_to(jnp.arange(1, m.d_state + 1, dtype=jnp.float32), (d_in, m.d_state))
    return {
        "in_proj": init_linear(k1, d, 2 * d_in),
        "conv_w": truncated_lecun(k2, (m.d_conv, d_in)),
        "conv_b": jnp.zeros((d_in,), dtype=jnp.float32),
        "x_proj": init_linear(k3, d_in, dtr + 2 * m.d_state),
        "dt_proj": {
            "w": truncated_lecun(k4, (dtr, d_in)),
            "b": jnp.log(jnp.expm1(jnp.full((d_in,), 0.01, dtype=jnp.float32))),
        },
        "A_log": jnp.log(a),
        "D": jnp.ones((d_in,), dtype=jnp.float32),
        "out_proj": init_linear(k5, d_in, d),
    }


def _ssm_inputs(params, cfg, x_conv):
    """Shared Delta/B/C computation. x_conv: (..., d_in) post-conv+silu."""
    m = cfg.mamba
    dtr = m.resolved_dt_rank(cfg.d_model)
    dbc = apply_linear(params["x_proj"], x_conv)
    dt, b, c = jnp.split(dbc, [dtr, dtr + m.d_state], axis=-1)
    dt = jax.nn.softplus(
        dt @ params["dt_proj"]["w"].astype(dt.dtype) + params["dt_proj"]["b"].astype(dt.dtype)
    )  # (..., d_in)
    return dt, b.astype(jnp.float32), c.astype(jnp.float32)


def _causal_conv(params, cfg, x, conv_state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over time. x: (B, S, d_in)."""
    m = cfg.mamba
    w = params["conv_w"].astype(x.dtype)  # (d_conv, d_in)
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], m.d_conv - 1, x.shape[-1]), dtype=x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S + d_conv - 1, d_in)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(m.d_conv))
    out = out + params["conv_b"].astype(x.dtype)
    new_state = xp[:, -(m.d_conv - 1) :] if m.d_conv > 1 else pad
    return out, new_state


def mamba_apply(params, cfg, x, state: Optional[dict] = None, peft: Optional[dict] = None, lora_scale: float = 1.0):
    """x: (B, S, d).  Returns (out, new_state); state used only for decode."""
    m = cfg.mamba
    b_sz, s, _ = x.shape
    d_in = m.expand * cfg.d_model
    peft = peft or {}

    xz = apply_linear(params["in_proj"], x, peft.get("in"), lora_scale)
    xr, z = jnp.split(xz, 2, axis=-1)

    conv_state = state["conv"] if state is not None else None
    x_conv, new_conv = _causal_conv(params, cfg, xr, conv_state)
    x_conv = jax.nn.silu(x_conv)

    dt, bmat, cmat = _ssm_inputs(params, cfg, x_conv)
    a = -jnp.exp(params["A_log"])  # (d_in, N) fp32
    xf = x_conv.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    # discretise: a_bar = exp(dt * A); b_bar*x = dt * B_t * x_t
    a_bar = jnp.exp(dtf[..., None] * a)                       # (B,S,d_in,N)
    bx = (dtf * xf)[..., None] * bmat[..., None, :]           # (B,S,d_in,N)

    if state is None:
        # parallel associative scan over time: h_t = a_t h_{t-1} + b_t
        def combine(lhs, rhs):
            a_l, b_l = lhs
            a_r, b_r = rhs
            return a_l * a_r, a_r * b_l + b_r

        _, h = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
        new_ssm = h[:, -1]
    else:
        h0 = state["ssm"].astype(jnp.float32)
        if s == 1:
            h = (a_bar[:, 0] * h0 + bx[:, 0])[:, None]
        else:  # short multi-token chunk with an incoming state
            def step(carry, inp):
                a_t, b_t = inp
                nxt = a_t * carry + b_t
                return nxt, nxt

            _, h = jax.lax.scan(step, h0, (a_bar.swapaxes(0, 1), bx.swapaxes(0, 1)))
            h = h.swapaxes(0, 1)
        new_ssm = h[:, -1]

    y = jnp.einsum("bsdn,bsn->bsd", h, cmat)                   # (B,S,d_in)
    y = y + params["D"].astype(jnp.float32) * xf
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = apply_linear(params["out_proj"], y, peft.get("out"), lora_scale)
    new_state = {"conv": new_conv.astype(jnp.float32), "ssm": new_ssm}
    return out, new_state


def init_mamba_state(cfg, batch: int):
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, d_in), dtype=jnp.float32),
        "ssm": jnp.zeros((batch, d_in, m.d_state), dtype=jnp.float32),
    }
