"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Training/prefill runs the **chunk-parallel** WKV form (chunk 16):
within a chunk everything is matmuls using the log-decay division trick
(numerically safe because the per-step log decay is clamped to
``[-DECAY_CLAMP, -1e-4]``, so intra-chunk exponents stay within fp32 range);
across chunks the state recurrence is a ``jax.lax.associative_scan``.
This mirrors the structure of the Pallas kernel (``repro.kernels.rwkv6_scan``)
and keeps every FLOP visible to ``cost_analysis``.

Decode runs the O(1) sequential step on a carried state
``{"wkv": (B,H,K,V), "shift_tm": (B,d), "shift_cm": (B,d)}``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.initializers import normal_init, truncated_lecun
from repro.nn.linear import apply_linear, init_linear

CHUNK = 16
DECAY_CLAMP = 4.0  # per-step |log decay| bound -> intra-chunk exp <= e^64


def init_rwkv_time_mix(key, cfg):
    r = cfg.rwkv
    d = cfg.d_model
    n_heads = d // r.head_dim
    keys = jax.random.split(key, 12)
    ts = r.token_shift_lora_dim
    p = {
        "mu_x": normal_init(keys[0], (d,), 0.02),
        # per-quantity ddlerp mix params + low-rank adjusters (w,k,v,r,g)
        "mu": normal_init(keys[1], (5, d), 0.02),
        "ts_lora_a": truncated_lecun(keys[2], (d, 5 * ts)),
        "ts_lora_b": jnp.zeros((5, ts, d), dtype=jnp.float32),
        "wr": init_linear(keys[3], d, d),
        "wk": init_linear(keys[4], d, d),
        "wv": init_linear(keys[5], d, d),
        "wg_a": truncated_lecun(keys[6], (d, r.gate_lora_dim)),
        "wg_b": truncated_lecun(keys[7], (r.gate_lora_dim, d)),
        "w0": normal_init(keys[8], (d,), 0.02) - 0.6,  # decay bias (pre-clamp)
        "wd_a": truncated_lecun(keys[9], (d, r.decay_lora_dim)),
        "wd_b": jnp.zeros((r.decay_lora_dim, d), dtype=jnp.float32),
        "u": normal_init(keys[10], (n_heads, r.head_dim), 0.02),  # bonus
        "ln_out_scale": jnp.ones((n_heads, r.head_dim), dtype=jnp.float32),
        "wo": init_linear(keys[11], d, d),
    }
    return p


def _token_shift(x, prev):
    """(B,S,d) shifted right by one; position 0 takes ``prev`` (B,d)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _ddlerp(params, x, xs):
    """Data-dependent interpolation producing the 5 mixed inputs (w,k,v,r,g)."""
    base = x + (xs - x) * params["mu_x"].astype(x.dtype)
    lora = jnp.tanh(base @ params["ts_lora_a"].astype(x.dtype))
    lora = lora.reshape(*x.shape[:-1], 5, -1)
    adj = jnp.einsum("...ct,ctd->...cd", lora, params["ts_lora_b"].astype(x.dtype))
    mu = params["mu"].astype(x.dtype) + adj  # (...,5,d)
    return x[..., None, :] + (xs - x)[..., None, :] * mu  # (...,5,d)


def _wkv_chunked(r, k, v, logw, u, s0=None):
    """Chunk-parallel WKV.  r,k,v: (B,S,H,K); logw: (B,S,H,K) (<0); u: (H,K);
    s0: optional initial state (B,H,K,V).

    Returns (out (B,S,H,K_v), final_state (B,H,K,V)).  K == V == head_dim.
    """
    b, s, h, kd = r.shape
    c = CHUNK
    if s % c:
        pad = c - s % c
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))  # pad log-decay 0 => w=1
    n = r.shape[1] // c
    shp = (b, n, c, h, kd)
    rc, kc, vc, lw = (t.reshape(shp).astype(jnp.float32) for t in (r, k, v, logw))

    lcum = jnp.cumsum(lw, axis=2)                    # inclusive L_t within chunk
    lprev = lcum - lw                                # exclusive L_{t-1}
    ltot = lcum[:, :, -1]                            # (B,N,H,K) chunk total

    q_ = rc * jnp.exp(lprev)                         # bounded <= |r|
    kappa = kc * jnp.exp(-lcum)                      # <= |k| * e^{c*clamp}
    kappa_end = kc * jnp.exp(ltot[:, :, None] - lcum)  # bounded <= |k|

    # intra-chunk attention-like matrix (strictly lower) + bonus diagonal
    amat = jnp.einsum("bnthk,bnjhk->bnhtj", q_, kappa)
    mask = jnp.tril(jnp.ones((c, c), dtype=bool), k=-1)
    amat = jnp.where(mask[None, None, None], amat, 0.0)
    diag = jnp.einsum("bnthk,hk,bnthk->bnth", rc, u.astype(jnp.float32), kc)
    intra = jnp.einsum("bnhtj,bnjhk->bnthk", amat, vc)
    intra = intra + diag[..., None] * vc

    # inter-chunk: scan chunk states S_n = diag(exp(ltot)) S_{n-1} + kappa_end^T V
    bmat = jnp.einsum("bnjhk,bnjhv->bnhkv", kappa_end, vc)  # (B,N,H,K,V)
    amat_c = jnp.exp(ltot)                                   # (B,N,H,K)

    def combine(lhs, rhs):
        a_l, b_l = lhs
        a_r, b_r = rhs
        return a_l * a_r, a_r[..., None] * b_l + b_r

    a_sc, b_sc = jax.lax.associative_scan(combine, (amat_c, bmat), axis=1)
    # state *entering* chunk n is the scanned value of chunk n-1 (zero for n=0)
    s_in = jnp.concatenate(
        [jnp.zeros_like(b_sc[:, :1]), b_sc[:, :-1]], axis=1
    )  # (B,N,H,K,V)
    final_state = b_sc[:, -1]
    if s0 is not None:
        s0f = s0.astype(jnp.float32)
        a_excl = jnp.concatenate(
            [jnp.ones_like(a_sc[:, :1]), a_sc[:, :-1]], axis=1
        )  # exclusive decay prefix per chunk (B,N,H,K)
        s_in = s_in + a_excl[..., None] * s0f[:, None]
        final_state = final_state + a_sc[:, -1][..., None] * s0f
    inter = jnp.einsum("bnthk,bnhkv->bnthv", q_, s_in)
    out = (intra + inter).reshape(b, n * c, h, kd)[:, :s]
    return out, final_state


def _wkv_step(state, r, k, v, logw, u):
    """Sequential single-token WKV.  state: (B,H,K,V); r,k,v,logw: (B,H,K)."""
    rf, kf, vf, w = (t.astype(jnp.float32) for t in (r, k, v, logw))
    kv = kf[..., :, None] * vf[..., None, :]                  # (B,H,K,V)
    out = jnp.einsum(
        "bhk,bhkv->bhv", rf, state + u.astype(jnp.float32)[None, :, :, None] * kv
    )
    new_state = jnp.exp(w)[..., None] * state + kv
    return out, new_state


def _group_norm(x, scale, eps=1e-5):
    """Per-head layernorm of (B,S,H,K)."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * scale


def time_mix_apply(params, cfg, x, state: Optional[dict] = None):
    """RWKV6 time-mix.  x: (B,S,d).  Returns (out, new_state_parts)."""
    r_cfg = cfg.rwkv
    b, s, d = x.shape
    hd = r_cfg.head_dim
    n_heads = d // hd

    prev = state["shift_tm"] if state is not None else jnp.zeros((b, d), dtype=x.dtype)
    xs = _token_shift(x, prev.astype(x.dtype))
    mixed = _ddlerp(params, x, xs)  # (B,S,5,d)
    xw, xk, xv, xr, xg = (mixed[..., i, :] for i in range(5))

    r = apply_linear(params["wr"], xr)
    k = apply_linear(params["wk"], xk)
    v = apply_linear(params["wv"], xv)
    g = jax.nn.silu((xg @ params["wg_a"].astype(x.dtype)) @ params["wg_b"].astype(x.dtype))

    decay_raw = params["w0"].astype(jnp.float32) + (
        jnp.tanh(xw.astype(jnp.float32) @ params["wd_a"]) @ params["wd_b"]
    )
    logw = -jnp.exp(decay_raw)
    logw = jnp.clip(logw, -DECAY_CLAMP, -1e-4)

    split = lambda t: t.reshape(b, s, n_heads, hd)
    rh, kh, vh, lwh = split(r), split(k), split(v), split(logw)

    if state is None:
        out, wkv_state = _wkv_chunked(rh, kh, vh, lwh, params["u"])
    elif s == 1:
        out, wkv_state = _wkv_step(
            state["wkv"], rh[:, 0], kh[:, 0], vh[:, 0], lwh[:, 0], params["u"]
        )
        out = out[:, None]
    else:  # prefill with an incoming state (serving)
        out, wkv_state = _wkv_chunked(rh, kh, vh, lwh, params["u"], s0=state["wkv"])

    out = _group_norm(out, params["ln_out_scale"].astype(jnp.float32))
    out = out.reshape(b, s, d).astype(x.dtype) * g
    out = apply_linear(params["wo"], out)
    new_state = {"wkv": wkv_state, "shift_tm": x[:, -1].astype(jnp.float32)}
    return out, new_state


def init_rwkv_channel_mix(key, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "mu_k": normal_init(k1, (d,), 0.02),
        "mu_r": normal_init(k2, (d,), 0.02),
        "wk": init_linear(k3, d, ff),
        "wv": init_linear(k4, ff, d),
        "wr": init_linear(jax.random.fold_in(key, 7), d, d),
    }


def channel_mix_apply(params, cfg, x, state: Optional[dict] = None, peft: Optional[dict] = None, lora_scale: float = 1.0):
    b, s, d = x.shape
    peft = peft or {}
    prev = state["shift_cm"] if state is not None else jnp.zeros((b, d), dtype=x.dtype)
    xs = _token_shift(x, prev.astype(x.dtype))
    xk = x + (xs - x) * params["mu_k"].astype(x.dtype)
    xr = x + (xs - x) * params["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(apply_linear(params["wk"], xk, peft.get("up"), lora_scale)))
    kv = apply_linear(params["wv"], k, peft.get("down"), lora_scale)
    out = jax.nn.sigmoid(apply_linear(params["wr"], xr)) * kv
    new_state = {"shift_cm": x[:, -1].astype(jnp.float32)}
    return out, new_state


def wkv_sequential_ref(r, k, v, logw, u):
    """Oracle: token-by-token WKV recurrence (B,S,H,K) -> (B,S,H,V)."""
    b, s, h, kd = r.shape
    state = jnp.zeros((b, h, kd, kd), dtype=jnp.float32)
    outs = []
    for t in range(s):
        o, state = _wkv_step(state, r[:, t], k[:, t], v[:, t], logw[:, t], u)
        outs.append(o)
    return jnp.stack(outs, axis=1), state


def init_rwkv_state(cfg, batch: int):
    d = cfg.d_model
    hd = cfg.rwkv.head_dim
    return {
        "wkv": jnp.zeros((batch, d // hd, hd, hd), dtype=jnp.float32),
        "shift_tm": jnp.zeros((batch, d), dtype=jnp.float32),
        "shift_cm": jnp.zeros((batch, d), dtype=jnp.float32),
    }
