"""Functional neural-network substrate (framework-internal; no flax).

Convention: every module is an ``init_*(key, ...) -> params`` /
``*_apply(params, ...) -> out`` pair over plain pytrees of jnp arrays.
Parameters are stored in ``param_dtype`` (fp32); activations are computed in
``dtype`` (bf16 by default).
"""
from repro.nn.initializers import normal_init, truncated_lecun, zeros_init
from repro.nn.linear import apply_linear, init_linear, lora_delta
from repro.nn.norms import apply_layernorm, apply_rmsnorm, init_layernorm, init_rmsnorm
from repro.nn.rotary import apply_rotary

__all__ = [
    "normal_init",
    "truncated_lecun",
    "zeros_init",
    "apply_linear",
    "init_linear",
    "lora_delta",
    "apply_layernorm",
    "apply_rmsnorm",
    "init_layernorm",
    "init_rmsnorm",
    "apply_rotary",
]
