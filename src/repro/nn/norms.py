"""RMSNorm / LayerNorm (computed in fp32, cast back to input dtype)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def apply_rmsnorm(params, x, eps: float = 1e-5):
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(orig)


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype=dtype), "bias": jnp.zeros((dim,), dtype=dtype)}


def apply_layernorm(params, x, eps: float = 1e-5):
    orig = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * (var + eps) ** -0.5
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(orig)
