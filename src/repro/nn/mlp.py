"""Feed-forward blocks: SwiGLU (llama family) and GELU (whisper), plus the
bottleneck Adapter used by the PEFT 'adapter' method."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.linear import apply_linear, init_linear
from repro.nn.initializers import truncated_lecun


def init_mlp(key, cfg, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.activation == "silu":
        kg, ku, kd = jax.random.split(key, 3)
        return {
            "gate": init_linear(kg, d, ff),
            "up": init_linear(ku, d, ff),
            "down": init_linear(kd, ff, d),
        }
    ku, kd = jax.random.split(key, 2)
    return {"up": init_linear(ku, d, ff, bias=True), "down": init_linear(kd, ff, d, bias=True)}


def mlp_apply(params, cfg, x, peft: Optional[dict] = None, lora_scale: float = 1.0):
    peft = peft or {}
    if "gate" in params:
        g = apply_linear(params["gate"], x, peft.get("gate"), lora_scale)
        u = apply_linear(params["up"], x, peft.get("up"), lora_scale)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(apply_linear(params["up"], x, peft.get("up"), lora_scale))
    return apply_linear(params["down"], h, peft.get("down"), lora_scale)


# ----------------------------------------------------------------- adapters
def init_adapter(key, d_model: int, adapter_dim: int):
    """Houlsby-style bottleneck adapter; up-proj starts at zero so the
    adapter is initially an identity residual."""
    kd, _ = jax.random.split(key)
    return {
        "down": {"w": truncated_lecun(kd, (d_model, adapter_dim))},
        "up": {"w": jnp.zeros((adapter_dim, d_model), dtype=jnp.float32)},
    }


def adapter_apply(params, x):
    h = jax.nn.gelu(apply_linear(params["down"], x))
    return x + apply_linear(params["up"], h)
