"""Linear projection with optional bias and LoRA side-branch.

LoRA params for a projection are ``{"a": (in, r), "b": (r, out)}``; the
scaling alpha/r is folded into ``b`` at init-time scale 0 (b starts at zero),
with the runtime ``scale`` passed explicitly so merged/unmerged paths agree.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.nn.initializers import truncated_lecun, zeros_init


def init_linear(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.float32):
    p = {"w": truncated_lecun(key, (d_in, d_out), dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def lora_delta(x, lora, scale: float):
    """``scale * (x @ a) @ b`` — the LoRA contribution, rank-r bottleneck."""
    a = lora["a"].astype(x.dtype)
    b = lora["b"].astype(x.dtype)
    return (x @ a) @ b * jnp.asarray(scale, dtype=x.dtype)


def apply_linear(params, x, lora: Optional[dict] = None, lora_scale: float = 1.0):
    w = params["w"].astype(x.dtype)
    y = x @ w
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    if lora is not None:
        y = y + lora_delta(x, lora, lora_scale)
    return y


def init_lora(key, d_in: int, d_out: int, rank: int, dtype=jnp.float32):
    """LoRA init per Hu et al.: a ~ normal, b = 0 (delta starts at zero)."""
    return {
        "a": truncated_lecun(key, (d_in, rank), dtype=dtype),
        "b": zeros_init(None, (rank, d_out), dtype=dtype),
    }
