"""Linear projection with optional bias and LoRA side-branch.

LoRA params for a projection are ``{"a": (in, r), "b": (r, out)}``; the
scaling alpha/r is folded into ``b`` at init-time scale 0 (b starts at zero),
with the runtime ``scale`` passed explicitly so merged/unmerged paths agree.

For multi-tenant serving a projection's peft node can instead be an
:class:`AdapterPool` — a stacked pool of adapters plus a per-row slot map —
in which case ``apply_linear`` dispatches to the segmented gather kernel so
every batch row applies its own tenant's adapter in one launch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.nn.initializers import truncated_lecun, zeros_init


@dataclass(frozen=True)
class AdapterPool:
    """Per-projection multi-tenant adapter pool riding inside a peft tree.

    ``a: (n_slots, d_in, r_max)`` and ``b: (n_slots, r_max, d_out)`` hold
    zero-padded adapters with the per-adapter LoRA scale (alpha/rank)
    pre-folded into ``b`` at slot-write time; ``ranks: (n_slots,)`` carries
    each slot's true rank for the in-kernel tail mask; ``idx: (batch,)``
    maps each batch row to its slot.  All fields are data (traced), so a
    slot swap rewrites pool contents without changing any static shape —
    the compiled serving step is reused across swaps.

    In the stacked-native layout every field gains a leading layer axis
    (``idx`` broadcast to ``(L, batch)``) so ``stacking.layer_view`` and
    scan-mode slicing pass through an ``AdapterPool`` like any other leaf.
    """

    a: Any
    b: Any
    idx: Any
    ranks: Any


jax.tree_util.register_dataclass(
    AdapterPool, data_fields=("a", "b", "idx", "ranks"), meta_fields=()
)


def init_linear(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.float32):
    p = {"w": truncated_lecun(key, (d_in, d_out), dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def lora_delta(x, lora, scale: float):
    """``scale * (x @ a) @ b`` — the LoRA contribution, rank-r bottleneck."""
    a = lora["a"].astype(x.dtype)
    b = lora["b"].astype(x.dtype)
    return (x @ a) @ b * jnp.asarray(scale, dtype=x.dtype)


def _pooled_linear(params, x, pool: AdapterPool):
    """Segmented multi-adapter projection: row i applies adapter
    ``pool.idx[i]``.  Main matmul and gathered LoRA branch run fused in one
    kernel launch; the per-adapter scale is already folded into ``pool.b``.
    """
    from repro.kernels.ops import segmented_lora

    w = params["w"].astype(x.dtype)
    lead = x.shape[:-1]
    xm = x.reshape(-1, x.shape[-1])
    idx = pool.idx
    if x.ndim == 3 and x.shape[1] != 1:
        idx = jnp.repeat(idx, x.shape[1])  # every token of a row shares its adapter
    y = segmented_lora(
        xm, w, pool.a.astype(x.dtype), pool.b.astype(x.dtype), idx, pool.ranks
    )
    y = y.reshape(*lead, w.shape[-1])
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def apply_linear(params, x, lora: Optional[dict] = None, lora_scale: float = 1.0):
    if isinstance(lora, AdapterPool):
        return _pooled_linear(params, x, lora)
    w = params["w"].astype(x.dtype)
    y = x @ w
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    if lora is not None:
        y = y + lora_delta(x, lora, lora_scale)
    return y


def init_lora(key, d_in: int, d_out: int, rank: int, dtype=jnp.float32):
    """LoRA init per Hu et al.: a ~ normal, b = 0 (delta starts at zero)."""
    return {
        "a": truncated_lecun(key, (d_in, rank), dtype=dtype),
        "b": zeros_init(None, (rank, d_out), dtype=dtype),
    }
