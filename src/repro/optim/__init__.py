from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm, sgdm_init, sgdm_update
from repro.optim.schedules import make_lr_schedule

__all__ = [
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "sgdm_init",
    "sgdm_update",
    "make_lr_schedule",
]
