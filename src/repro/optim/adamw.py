"""AdamW and SGD-momentum, functional pytree optimizers.

The paper's setting (AdamW, BF16 numerics for the base model, fp32 optimizer
states on the PEFT params only — the frozen base holds no optimizer state,
which is exactly the memory argument of paper Fig. 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), dtype=jnp.int32),
    }


def adamw_update(
    grads,
    state,
    params,
    *,
    lr,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
):
    count = state["count"] + 1
    b1c = 1.0 - beta1 ** count.astype(jnp.float32)
    b2c = 1.0 - beta2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m2 = beta1 * m + (1 - beta1) * g
        v2 = beta2 * v + (1 - beta2) * jnp.square(g)
        step = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + eps)
        new_p = p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))
        return m2, v2, new_p.astype(p.dtype)

    flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
    m = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": m, "v": v, "count": count}


def sgdm_init(params):
    return {"mom": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}


def sgdm_update(grads, state, params, *, lr, momentum: float = 0.9):
    def upd(g, m, p):
        m2 = momentum * m + g.astype(jnp.float32)
        return m2, (p.astype(jnp.float32) - lr * m2).astype(p.dtype)

    flat = jax.tree.map(upd, grads, state["mom"], params)
    mom = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mom": mom}
