"""AdamW and SGD-momentum, functional pytree optimizers.

The paper's setting (AdamW, BF16 numerics for the base model, fp32 optimizer
states on the PEFT params only — the frozen base holds no optimizer state,
which is exactly the memory argument of paper Fig. 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import stacking


def _global_sq_sum(grads):
    """Sum of squares over every element of ``grads``, computed through a
    layout-canonical reduction.

    A homogeneous per-layer **list** is first stacked into the ``(L, ...)``
    leaf layout, so both layouts lower to the *identical* reduce subgraph
    (same operand shapes, same fusion decisions) and produce bit-identical
    totals — XLA fuses per-leaf scalar reduces and trailing-axis reduces
    differently, so merely summing the same values in the same order is not
    enough for cross-layout bit parity (the federated list-vs-stacked
    parity baseline depends on this).
    """
    if isinstance(grads, (list, tuple)) and stacking.is_stackable(list(grads)):
        grads = stacking.stack_params(list(grads))
    leaves = [g.astype(jnp.float32) for g in jax.tree.leaves(grads)]
    if not leaves:
        return jnp.zeros((), dtype=jnp.float32)
    lead = {g.shape[0] for g in leaves if g.ndim}
    if not isinstance(grads, (list, tuple)) and len(lead) == 1 and all(
        g.ndim for g in leaves
    ):
        # stacked layout: per-leaf trailing-axis reduce -> (L,) partials,
        # arranged layer-major, one final vector reduce
        parts = [
            jnp.sum(jnp.square(g), axis=tuple(range(1, g.ndim))) for g in leaves
        ]
        return jnp.sum(jnp.stack(parts, axis=-1).reshape(-1))
    # heterogeneous trees: plain per-leaf reduction (no cross-layout twin)
    return jnp.sum(jnp.stack([jnp.sum(jnp.square(g)) for g in leaves]))


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(_global_sq_sum(grads))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), dtype=jnp.int32),
    }


def adamw_update(
    grads,
    state,
    params,
    *,
    lr,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
):
    count = state["count"] + 1
    b1c = 1.0 - beta1 ** count.astype(jnp.float32)
    b2c = 1.0 - beta2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m2 = beta1 * m + (1 - beta1) * g
        v2 = beta2 * v + (1 - beta2) * jnp.square(g)
        step = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + eps)
        new_p = p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))
        return m2, v2, new_p.astype(p.dtype)

    flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
    m = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": m, "v": v, "count": count}


def sgdm_init(params):
    return {"mom": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}


def sgdm_update(grads, state, params, *, lr, momentum: float = 0.9):
    def upd(g, m, p):
        m2 = momentum * m + g.astype(jnp.float32)
        return m2, (p.astype(jnp.float32) - lr * m2).astype(p.dtype)

    flat = jax.tree.map(upd, grads, state["mom"], params)
    mom = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mom": mom}
