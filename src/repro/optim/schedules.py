"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def make_lr_schedule(kind: str, base_lr: float, warmup_steps: int, total_steps: int):
    def sched(step):
        step = jnp.asarray(step, dtype=jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / jnp.maximum(warmup_steps, 1))
        frac = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        if kind == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        elif kind == "linear":
            decay = 1.0 - frac
        elif kind == "constant":
            decay = 1.0
        else:
            raise ValueError(f"unknown schedule {kind!r}")
        return base_lr * warm * decay

    return sched
