"""Losses and metrics."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits, labels, mask=None, z_loss_coef: float = 0.0):
    """Token-level cross entropy in fp32.

    logits: (..., V); labels: (...) int32; mask: (...) {0,1}.
    Returns (mean_loss, metrics dict).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    if z_loss_coef > 0.0:
        nll = nll + z_loss_coef * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, axis=-1) == labels) * mask) / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}


def lm_shift_labels(tokens):
    """Next-token prediction: inputs tokens[:, :-1], labels tokens[:, 1:]."""
    return tokens[:, :-1], tokens[:, 1:]
