"""Stacked-native layer layout: converters and layout predicates.

Two on-host layouts exist for "a stack of L per-layer pytrees":

* **list**    — ``[tree_0, ..., tree_{L-1}]``: one pytree per layer.  The
  historical layout.  Flattening a model's parameters in this layout yields
  O(L·k) leaves, so every jit dispatch pays O(L·k) arg-flattening, and scan
  execution must ``jnp.stack`` the layers *inside* the traced program —
  materializing a second full copy of the frozen base weights per step.
* **stacked** — a single pytree whose leaves carry a leading ``(L, ...)``
  layer axis (structure-of-arrays).  O(k) leaves regardless of depth;
  ``lax.scan``/``jnp.take`` consume it directly with zero traced stacking.

Stacked is the native layout everywhere the stack is *homogeneous* (every
layer has identical structure and shapes).  Heterogeneous stacks — hybrid
attn/mamba interleaves, MoE-every-other-layer patterns — keep the list
layout, which ``stack_apply``'s ``unroll``/``group`` modes consume as
before.  All library entry points accept either layout; these helpers are
the single place layout decisions live.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def is_stacked(layers) -> bool:
    """True for the stacked (single-pytree) layout, False for list layout."""
    return not isinstance(layers, (list, tuple))


def is_stackable(trees: Sequence) -> bool:
    """Can this per-layer list be stacked?  Requires identical structure and
    leaf shapes across layers (a homogeneous stack)."""
    if not trees:
        return True

    def sig(t):
        return [(jnp.shape(x), jnp.result_type(x)) for x in jax.tree.leaves(t)]

    ref_struct = jax.tree.structure(trees[0])
    ref_sig = sig(trees[0])
    for t in trees[1:]:
        # dtype is part of the signature: jnp.stack would silently promote a
        # mixed-dtype stack, breaking dtype round-trips and bit parity
        if jax.tree.structure(t) != ref_struct or sig(t) != ref_sig:
            return False
    return True


def stack_params(layers: Sequence):
    """list layout -> stacked layout (one ``jnp.stack`` per param kind).

    Raises ``ValueError`` for heterogeneous stacks, which have no stacked
    representation.
    """
    if is_stacked(layers):
        return layers
    if not is_stackable(layers):
        raise ValueError(
            "cannot stack a heterogeneous layer list (per-layer structures "
            "or shapes differ); keep the list layout for this stack"
        )
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def unstack_params(layers, num_layers: Optional[int] = None) -> list:
    """stacked layout -> list layout (per-layer slices).

    ``num_layers`` is only needed for leafless stacked trees (e.g. the empty
    PEFT tree of ``method='none'``).
    """
    if not is_stacked(layers):
        return list(layers)
    n = num_layers if num_layers is not None else stack_size(layers)
    if n is None:
        raise ValueError("cannot infer layer count of a leafless stacked tree")
    return [jax.tree.map(lambda x: x[l], layers) for l in range(n)]


def stack_size(layers) -> Optional[int]:
    """Number of layers in either layout (None for a leafless stacked tree)."""
    if not is_stacked(layers):
        return len(layers)
    leaves = jax.tree.leaves(layers)
    return int(leaves[0].shape[0]) if leaves else None


def layer_view(layers, l):
    """Layer ``l`` as a per-layer pytree (a slice view in stacked layout)."""
    if not is_stacked(layers):
        return layers[l]
    return jax.tree.map(lambda x: x[l], layers)


def maybe_stack(layers: Sequence, layout: str = "auto"):
    """Apply an init-time layout policy to a freshly built per-layer list.

    ``auto``    — stacked when homogeneous, list otherwise (the default).
    ``stacked`` — force stacked (raises for heterogeneous stacks).
    ``list``    — keep the list layout (legacy/bench baseline).
    """
    if layout == "list":
        return list(layers)
    if layout == "stacked":
        return stack_params(layers)
    if layout == "auto":
        return stack_params(layers) if is_stackable(layers) else list(layers)
    raise ValueError(f"unknown layer layout {layout!r}")


def select_layers(mask, take_tree, keep_tree, axis: int = 0):
    """Per-layer select on stacked trees: layer ``l`` comes from
    ``take_tree`` where ``mask[l]`` else from ``keep_tree``.  ``axis`` is
    the layer axis (1 for cohort-stacked ``(N, L, ...)`` leaves).  Exact
    copies (``jnp.where`` on a bool mask), so it is bit-identical to the
    list-layout per-layer python selection it replaces."""
    mask = jnp.asarray(mask)

    def pick(t, k):
        m = mask.reshape((1,) * axis + mask.shape + (1,) * (t.ndim - axis - 1))
        return jnp.where(m, t, k)

    return jax.tree.map(pick, take_tree, keep_tree)
