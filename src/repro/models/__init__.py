"""Architecture assembly: layer blocks, decoder stacks, registry."""
from repro.models import stacking
from repro.models.registry import build_model, init_params, model_apply
from repro.models.stacking import stack_params, unstack_params

__all__ = [
    "build_model",
    "init_params",
    "model_apply",
    "stacking",
    "stack_params",
    "unstack_params",
]
