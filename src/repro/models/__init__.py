"""Architecture assembly: layer blocks, decoder stacks, registry."""
from repro.models.registry import build_model, init_params, model_apply

__all__ = ["build_model", "init_params", "model_apply"]
