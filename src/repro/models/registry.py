"""Model registry: uniform init/apply across all 10 assigned architectures.

``init_params(key, cfg)``     -> param pytree
``model_apply(params, cfg, batch, **kw)`` -> (logits, aux, caches)

``batch`` keys by modality:
  text   : {"tokens": (B, S)}
  vision : {"tokens": (B, S), "patches": (B, P, d)}   (stub frontend)
  audio  : {"tokens": (B, S_dec), "frames": (B, S_enc, d)}  (stub frontend)

Layer stacks are emitted **stacked-native** — one leaf per param kind with a
leading ``(L, ...)`` layer axis — whenever the stack is homogeneous;
heterogeneous stacks (hybrid interleaves) keep the per-layer list layout.
``stack_params``/``unstack_params`` (re-exported from
:mod:`repro.models.stacking`) convert between the two for the
heterogeneous/hetlora and dry-run ``unroll`` paths.
"""
from __future__ import annotations

from repro.models import encdec, transformer
from repro.models.stacking import (  # noqa: F401  (public converter API)
    is_stacked,
    stack_params,
    unstack_params,
)


def build_model(cfg):
    """Return (init_fn, apply_fn) for the architecture family."""
    return init_params, model_apply


def init_params(key, cfg, layout: str = "auto"):
    if cfg.is_encoder_decoder:
        return encdec.init_encdec(key, cfg, layout)
    return transformer.init_lm(key, cfg, layout)


def model_apply(
    params,
    cfg,
    batch,
    *,
    drops=None,
    caches=None,
    enc_kvs=None,
    positions=None,
    peft=None,
    lora_scale: float = 1.0,
    stack_mode: str = "unroll",
    active_idx=None,
    remat: bool = False,
):
    if cfg.is_encoder_decoder:
        if enc_kvs is None:
            enc_out = encdec.encode(
                params,
                cfg,
                batch["frames"],
                peft=None,
                stack_mode=stack_mode if stack_mode in ("unroll", "scan") else "unroll",
            )
            enc_kvs = encdec.encoder_cross_kvs(params, cfg, enc_out)
        return encdec.decode(
            params,
            cfg,
            batch["tokens"],
            enc_kvs,
            positions=positions,
            drops=drops,
            caches=caches,
            peft=peft,
            lora_scale=lora_scale,
            stack_mode=stack_mode if stack_mode in ("unroll", "scan") else "unroll",
        )
    prefix = batch.get("patches") if cfg.modality == "vision" else None
    return transformer.lm_apply(
        params,
        cfg,
        batch["tokens"],
        positions=positions,
        prefix_embeds=prefix,
        drops=drops,
        caches=caches,
        peft=peft,
        lora_scale=lora_scale,
        stack_mode=stack_mode,
        active_idx=active_idx,
        remat=remat,
    )


def default_stack_mode(cfg) -> str:
    """Preferred training stack mode per family (dry-run overrides to unroll)."""
    if cfg.family == "hybrid":
        return "group"
    return "scan"
