"""Whisper-style encoder-decoder (audio backbone; conv frontend stubbed).

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: the encoder consumes precomputed frame embeddings
(B, frontend_seq, d_model) from ``input_specs``.  Everything downstream —
bidirectional encoder, causal decoder with cross-attention, KV-cache decode —
is fully implemented.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models import stacking
from repro.models.layers import init_layer, init_layer_cache
from repro.models.transformer import _norm_apply, _norm_init, stack_apply
from repro.nn.attention import encode_cross_kv
from repro.nn.initializers import normal_init


def sinusoidal_positions(length: int, dim: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / dim))
    pe = jnp.zeros((length, dim), dtype=jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def init_encdec(key, cfg, layout: str = "auto"):
    k_enc, k_dec, k_emb, k_pos = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.num_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    enc_layers = [
        init_layer(enc_keys[l], cfg, l, force_kind="attn")
        for l in range(cfg.num_encoder_layers)
    ]
    dec_layers = [init_layer(dec_keys[l], cfg, l) for l in range(cfg.num_layers)]
    return {
        "encoder": {
            "layers": stacking.maybe_stack(enc_layers, layout),
            "final_norm": _norm_init(cfg, cfg.d_model),
        },
        "decoder": {
            "embed": normal_init(k_emb, (cfg.vocab_size, cfg.d_model)),
            "pos_embed": normal_init(k_pos, (cfg.max_seq_len, cfg.d_model)),
            "layers": stacking.maybe_stack(dec_layers, layout),
            "final_norm": _norm_init(cfg, cfg.d_model),
        },
    }


def encode(
    params,
    cfg,
    frames,
    *,
    drops=None,
    peft: Optional[Sequence] = None,
    lora_scale: float = 1.0,
    stack_mode: str = "unroll",
):
    """frames: (B, S_enc, d) stub embeddings -> (B, S_enc, d) encoder states."""
    compute_dtype = jnp.dtype(cfg.dtype)
    s = frames.shape[1]
    h = frames.astype(compute_dtype) + sinusoidal_positions(s, cfg.d_model).astype(
        compute_dtype
    )
    h, _, _ = stack_apply(
        params["encoder"]["layers"],
        cfg,
        h,
        positions=jnp.arange(s),
        causal=False,
        drops=drops,
        peft=peft,
        lora_scale=lora_scale,
        stack_mode=stack_mode,
    )
    return _norm_apply(cfg, params["encoder"]["final_norm"], h)


def encoder_cross_kvs(params, cfg, enc_out):
    """Precompute per-decoder-layer cross K/V once per sequence.  Returns a
    tree in the same layout as the decoder stack: one vmapped projection
    over the stacked layer axis, or a per-layer list."""
    layers = params["decoder"]["layers"]
    if stacking.is_stacked(layers):
        return jax.vmap(lambda cross: encode_cross_kv(cross, cfg, enc_out))(
            layers["cross"]
        )
    return [encode_cross_kv(layer["cross"], cfg, enc_out) for layer in layers]


def decode(
    params,
    cfg,
    tokens,
    enc_kvs,
    *,
    positions=None,
    drops=None,
    caches=None,
    peft: Optional[Sequence] = None,
    lora_scale: float = 1.0,
    stack_mode: str = "unroll",
):
    """tokens: (B, S_dec).  Returns (logits, aux, new_caches)."""
    compute_dtype = jnp.dtype(cfg.dtype)
    dec = params["decoder"]
    h = dec["embed"][tokens].astype(compute_dtype)
    if positions is None:
        positions = jnp.arange(tokens.shape[1])
    h = h + dec["pos_embed"].astype(compute_dtype)[positions]

    h, aux, new_caches = stack_apply(
        dec["layers"],
        cfg,
        h,
        positions=positions,
        causal=True,
        drops=drops,
        caches=caches,
        enc_kvs=enc_kvs,
        peft=peft,
        lora_scale=lora_scale,
        stack_mode=stack_mode,
    )
    h = _norm_apply(cfg, dec["final_norm"], h)
    logits = h @ dec["embed"].T.astype(compute_dtype)  # whisper ties output proj
    return logits, aux, new_caches


def init_decoder_caches(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return [init_layer_cache(cfg, l, batch, max_len, dtype) for l in range(cfg.num_layers)]
