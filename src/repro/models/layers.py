"""Per-layer residual blocks with a uniform (h, cache) -> (h, aux, cache)
interface, so the STLD gate (repro.core.stld) can wrap any layer kind.

Layer kinds (``layer_kind(cfg, l)``):
  * ``attn``   — pre-norm GQA attention + (MoE | MLP)
  * ``mamba``  — pre-norm Mamba block + (MoE | MLP)        (hybrid archs)
  * ``rwkv``   — RWKV6 time-mix + channel-mix              (ssm archs)
  * ``encdec`` — self-attn + cross-attn + MLP              (whisper decoder)
  * ``enc``    — bidirectional attn + MLP                  (whisper encoder)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.attention import (
    attention_apply,
    cross_attention_apply,
    init_attention,
    init_cross_attention,
)
from repro.nn.mamba import init_mamba, init_mamba_state, mamba_apply
from repro.nn.mlp import adapter_apply, init_mlp, mlp_apply
from repro.nn.moe import init_moe, moe_apply
from repro.nn.norms import (
    apply_layernorm,
    apply_rmsnorm,
    init_layernorm,
    init_rmsnorm,
)
from repro.nn.rwkv import (
    channel_mix_apply,
    init_rwkv_channel_mix,
    init_rwkv_state,
    init_rwkv_time_mix,
    time_mix_apply,
)


def layer_kind(cfg, l: int) -> str:
    if cfg.family == "ssm":
        return "rwkv"
    if cfg.family == "audio":
        return "encdec"
    if cfg.family == "hybrid" and not cfg.is_attention_layer(l):
        return "mamba"
    return "attn"


def _norm_pair(cfg, dim):
    if cfg.activation == "gelu":  # whisper-style layernorm
        return init_layernorm(dim)
    return init_rmsnorm(dim)


def _apply_norm(cfg, p, x):
    if "bias" in p:
        return apply_layernorm(p, x, cfg.norm_eps)
    return apply_rmsnorm(p, x, cfg.norm_eps)


def init_layer(key, cfg, l: int, force_kind: Optional[str] = None):
    """Parameters for layer ``l`` of the decoder stack.

    ``force_kind='attn'`` is used by the whisper *encoder* (plain
    bidirectional attention layers inside an ``audio`` config)."""
    kind = force_kind or layer_kind(cfg, l)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"norm1": _norm_pair(cfg, cfg.d_model), "norm2": _norm_pair(cfg, cfg.d_model)}
    if kind == "rwkv":
        p["time_mix"] = init_rwkv_time_mix(k1, cfg)
        p["channel_mix"] = init_rwkv_channel_mix(k2, cfg)
        return p
    if kind == "mamba":
        p["mamba"] = init_mamba(k1, cfg)
    else:
        p["attn"] = init_attention(k1, cfg)
    if kind == "encdec":
        p["cross"] = init_cross_attention(k3, cfg)
        p["norm_cross"] = _norm_pair(cfg, cfg.d_model)
    if cfg.is_moe_layer(l):
        p["moe"] = init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k2, cfg)
    return p


def init_layer_cache(cfg, l: int, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decode-time cache/state for layer ``l``."""
    kind = layer_kind(cfg, l)
    if kind == "rwkv":
        return init_rwkv_state(cfg, batch)
    if kind == "mamba":
        return init_mamba_state(cfg, batch)
    hd = cfg.resolved_head_dim
    cache_len = max_len
    if cfg.sliding_window is not None:
        cache_len = min(max_len, cfg.sliding_window)
    return {
        "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype=dtype),
        "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype=dtype),
        "pos": jnp.zeros((), dtype=jnp.int32),
    }


def params_kind(params) -> str:
    """Infer the layer kind from its parameter structure (scan-safe: no
    layer index needed)."""
    if "time_mix" in params:
        return "rwkv"
    if "mamba" in params:
        return "mamba"
    if "cross" in params:
        return "encdec"
    return "attn"


def layer_apply(
    params,
    cfg,
    h,
    *,
    positions,
    causal: bool = True,
    cache: Optional[dict] = None,
    enc_kv: Optional[dict] = None,
    peft: Optional[dict] = None,
    lora_scale: float = 1.0,
):
    """One residual block.  Returns (h, moe_aux, new_cache)."""
    kind = params_kind(params)
    peft = peft or {}
    aux = jnp.zeros((), dtype=jnp.float32)
    new_cache = cache

    if kind == "rwkv":
        tm_out, tm_state = time_mix_apply(
            params["time_mix"], cfg, _apply_norm(cfg, params["norm1"], h), state=cache
        )
        if "bias_attn" in peft:
            tm_out = tm_out + peft["bias_attn"].astype(tm_out.dtype)
        h = h + tm_out
        cm_out, cm_state = channel_mix_apply(
            params["channel_mix"],
            cfg,
            _apply_norm(cfg, params["norm2"], h),
            state=cache,
            peft=peft.get("cm"),
            lora_scale=lora_scale,
        )
        if "adapter_mlp" in peft:
            cm_out = adapter_apply(peft["adapter_mlp"], cm_out)
        if "bias_mlp" in peft:
            cm_out = cm_out + peft["bias_mlp"].astype(cm_out.dtype)
        h = h + cm_out
        if cache is not None:
            new_cache = {**tm_state, **cm_state}
        return h, aux, new_cache

    if kind == "mamba":
        out, state = mamba_apply(
            params["mamba"],
            cfg,
            _apply_norm(cfg, params["norm1"], h),
            state=cache,
            peft=peft.get("mamba"),
            lora_scale=lora_scale,
        )
        if "bias_attn" in peft:
            out = out + peft["bias_attn"].astype(out.dtype)
        h = h + out
    else:
        out, attn_cache = attention_apply(
            params["attn"],
            cfg,
            _apply_norm(cfg, params["norm1"], h),
            positions,
            causal=causal,
            cache=cache,
            peft=peft.get("attn"),
            lora_scale=lora_scale,
        )
        if "adapter_attn" in peft:
            out = adapter_apply(peft["adapter_attn"], out)
        if "bias_attn" in peft:
            out = out + peft["bias_attn"].astype(out.dtype)
        h = h + out

    if kind == "encdec" and enc_kv is not None:
        out = cross_attention_apply(
            params["cross"],
            cfg,
            _apply_norm(cfg, params["norm_cross"], h),
            enc_kv,
            peft=peft.get("cross"),
            lora_scale=lora_scale,
        )
        h = h + out

    x = _apply_norm(cfg, params["norm2"], h)
    if "moe" in params:
        out, aux = moe_apply(params["moe"], cfg, x)
    else:
        out = mlp_apply(params["mlp"], cfg, x, peft.get("mlp"), lora_scale)
    if "adapter_mlp" in peft:
        out = adapter_apply(peft["adapter_mlp"], out)
    if "bias_mlp" in peft:
        out = out + peft["bias_mlp"].astype(out.dtype)
    h = h + out

    if kind == "mamba":
        new_cache = state if cache is not None else None
    elif kind in ("attn", "encdec"):
        new_cache = attn_cache
    return h, aux.astype(jnp.float32), new_cache
