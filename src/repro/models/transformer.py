"""Decoder-stack assembly with STLD-gated layers.

Layer stacks arrive in either layout (see :mod:`repro.models.stacking`):
**stacked** — one pytree with a leading ``(L, ...)`` layer axis on every
leaf, the native layout for homogeneous stacks — or **list** — one pytree
per layer, kept for heterogeneous stacks (hybrid interleaves) and legacy
callers.  ``scan``/``gather``/``group`` consume a stacked tree directly
(zero ``jnp.stack`` inside the traced program); a list is stacked at trace
time as before.

Stack execution modes (``stack_mode``):

* ``unroll`` — python loop over layers (per-layer slices of a stacked
  tree).  Used by the dry-run so ``cost_analysis`` counts every layer (a
  ``lax.scan`` body is costed once — measured 10x undercount, see DESIGN.md
  §8) and by heterogeneous stacks.
* ``scan``   — ``lax.scan`` over the stacked layer params (homogeneous
  stacks): fast compiles for deep models; the training default.
* ``group``  — ``lax.scan`` over groups of ``cfg.layer_period`` layers
  (Jamba's mamba/attn/MoE interleave repeats with period 8).
* ``gather`` — gather-STLD (core.stld): static active count, traced indices,
  a pure ``jnp.take`` on the stacked leaves, scan over the sub-stack.

STLD gating (``drops``) composes with ``unroll``/``scan``/``group``;
``gather`` replaces it with index sampling.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import stld
from repro.models import stacking
from repro.models.layers import init_layer, init_layer_cache, layer_apply
from repro.nn.initializers import normal_init
from repro.nn.norms import apply_layernorm, apply_rmsnorm, init_layernorm, init_rmsnorm

_EMPTY = object()  # sentinel for absent scan inputs


def _stack(trees: Sequence):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _as_stacked(trees):
    """Stacked tree for scan-family modes: pass-through when already
    stacked, trace-time stack for list-layout callers."""
    return trees if stacking.is_stacked(trees) else _stack(list(trees))


def _homogeneous(trees) -> bool:
    if stacking.is_stacked(trees):
        return True
    return stacking.is_stackable(list(trees))


def _norm_init(cfg, dim):
    return init_layernorm(dim) if cfg.activation == "gelu" else init_rmsnorm(dim)


def _norm_apply(cfg, p, x):
    return apply_layernorm(p, x, cfg.norm_eps) if "bias" in p else apply_rmsnorm(p, x, cfg.norm_eps)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_lm(key, cfg, layout: str = "auto"):
    """Decoder-only LM (also the VLM/MoE/hybrid/ssm backbone).

    ``layout`` picks the layer-stack representation: ``auto`` (default)
    emits the stacked ``(L, ...)`` layout whenever the stack is homogeneous
    and falls back to the per-layer list for heterogeneous stacks;
    ``list``/``stacked`` force a layout (see :mod:`repro.models.stacking`).
    """
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = [init_layer(layer_keys[l], cfg, l) for l in range(cfg.num_layers)]
    params = {
        "embed": normal_init(k_emb, (cfg.vocab_size, cfg.d_model)),
        "layers": stacking.maybe_stack(layers, layout),
        "final_norm": _norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(k_head, (cfg.d_model, cfg.vocab_size))
    return params


def init_caches(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, layout: str = "list"):
    """Per-layer decode caches.  ``layout='stacked'`` returns one pytree
    with a leading ``(L, ...)`` axis per leaf (homogeneous stacks only) —
    O(k) jit arguments instead of O(L·k), which is what keeps the serving
    step inside the jaxpr leaf budget."""
    caches = [init_layer_cache(cfg, l, batch, max_len, dtype) for l in range(cfg.num_layers)]
    if layout == "stacked":
        return stacking.stack_params(caches)
    if layout != "list":
        raise ValueError(f"unknown cache layout {layout!r}")
    return caches


# --------------------------------------------------------------------------
# stack execution
# --------------------------------------------------------------------------
def stack_apply(
    layers: Sequence,
    cfg,
    h,
    *,
    positions,
    causal: bool = True,
    drops=None,
    caches: Optional[Sequence] = None,
    enc_kvs: Optional[Sequence] = None,
    peft: Optional[Sequence] = None,
    lora_scale: float = 1.0,
    stack_mode: str = "unroll",
    active_idx=None,
    remat: bool = False,
):
    """Run the layer stack.  Returns (h, aux_sum, new_caches).

    ``layers``/``peft``/``enc_kvs`` accept either layout: a per-layer list
    or a stacked tree with a leading layer axis.
    """
    num_layers = stacking.stack_size(layers)

    def block(p_l, peft_l, enc_kv_l, h, cache_l):
        fn = lambda hh, cc: layer_apply(
            p_l,
            cfg,
            hh,
            positions=positions,
            causal=causal,
            cache=cc,
            enc_kv=enc_kv_l,
            peft=peft_l,
            lora_scale=lora_scale,
        )
        if remat:
            fn = jax.checkpoint(fn)
        return fn(h, cache_l)

    # ---------------------------------------------------------- unroll
    if stack_mode == "unroll":
        aux_sum = jnp.zeros((), dtype=jnp.float32)
        caches_stacked = caches is not None and stacking.is_stacked(caches)
        new_caches = [] if caches is not None else None
        for l in range(num_layers):
            cache_l = stacking.layer_view(caches, l) if caches is not None else None
            peft_l = stacking.layer_view(peft, l) if peft is not None else None
            enc_kv_l = stacking.layer_view(enc_kvs, l) if enc_kvs is not None else None
            p_l = stacking.layer_view(layers, l)
            fn = lambda hh, cc, p=p_l, pf=peft_l, ek=enc_kv_l: block(p, pf, ek, hh, cc)
            if drops is not None:
                h, aux, cache_l = stld.gate(fn, drops[l], h, cache_l)
            else:
                h, aux, cache_l = fn(h, cache_l)
            aux_sum = aux_sum + aux
            if new_caches is not None:
                new_caches.append(cache_l)
        if caches_stacked:
            new_caches = stacking.stack_params(new_caches)
        return h, aux_sum, new_caches

    # -------------------------------------------------- gather_unroll
    # gather-STLD with a python loop over the k gathered layers: same
    # compiled semantics as "gather", but every block appears in the HLO so
    # cost_analysis is exact (a lax.scan body is costed once — DESIGN.md §8).
    if stack_mode == "gather_unroll":
        if not _homogeneous(layers):
            raise ValueError("gather_unroll requires a homogeneous stack")
        assert active_idx is not None, "gather_unroll needs active_idx"
        stacked = _as_stacked(layers)
        peft_s = _as_stacked(peft) if peft is not None else None
        take = lambda tree, i: jax.tree.map(lambda x: x[i], tree)
        aux_sum = jnp.zeros((), dtype=jnp.float32)
        for j in range(active_idx.shape[0]):
            idx = active_idx[j]
            p_l = take(stacked, idx)
            peft_l = take(peft_s, idx) if peft_s is not None else None
            h, aux, _ = block(p_l, peft_l, None, h, None)
            aux_sum = aux_sum + aux
        return h, aux_sum, None

    # ------------------------------------------------------ scan / gather
    if stack_mode in ("scan", "gather"):
        if not _homogeneous(layers):
            raise ValueError(f"stack_mode={stack_mode!r} requires a homogeneous stack")
        cols = {
            "params": _as_stacked(layers),
            "peft": _as_stacked(peft) if peft is not None else _EMPTY,
            "caches": _as_stacked(caches) if caches is not None else _EMPTY,
            "enc": _as_stacked(enc_kvs) if enc_kvs is not None else _EMPTY,
            "drops": drops if drops is not None else _EMPTY,
        }
        if stack_mode == "gather":
            assert active_idx is not None, "gather mode needs active_idx"
            cols["drops"] = _EMPTY  # gathering *is* the dropout
            for name in ("params", "peft", "caches", "enc"):
                if cols[name] is not _EMPTY:
                    cols[name] = jax.tree.map(
                        lambda x: jnp.take(x, active_idx, axis=0), cols[name]
                    )
        order = [k for k, v in cols.items() if v is not _EMPTY]
        xs = tuple(cols[k] for k in order)

        def body(h, xs_vals):
            v = dict(zip(order, xs_vals))
            fn = lambda hh, cc: block(v["params"], v.get("peft"), v.get("enc"), hh, cc)
            cache_l = v.get("caches")
            if "drops" in v:
                h, aux, new_cache = stld.gate(fn, v["drops"], h, cache_l)
            else:
                h, aux, new_cache = fn(h, cache_l)
            return h, (aux, new_cache if caches is not None else jnp.zeros((0,)))

        h, (auxs, new_caches_s) = jax.lax.scan(body, h, xs)
        aux_sum = jnp.sum(auxs)
        if caches is None:
            return h, aux_sum, None
        if stacking.is_stacked(caches):
            # stacked in, stacked out: the scan's (L, ...) output IS the
            # stacked layout — no per-layer unstack in the traced program
            return h, aux_sum, new_caches_s
        new_caches = [jax.tree.map(lambda x: x[i], new_caches_s) for i in range(num_layers)]
        return h, aux_sum, new_caches

    # ------------------------------------------------------------- group
    if stack_mode == "group":
        period = cfg.layer_period
        if num_layers % period:
            raise ValueError("group mode requires num_layers % layer_period == 0")
        n_groups = num_layers // period

        def by_slot(seq):
            if stacking.is_stacked(seq):
                # stacked (L, ...) leaves: a (n_groups, period) reshape + slot
                # slice replaces the trace-time per-slot jnp.stack
                grouped = jax.tree.map(
                    lambda x: x.reshape((n_groups, period) + x.shape[1:]), seq
                )
                return tuple(
                    jax.tree.map(lambda x: x[:, s], grouped) for s in range(period)
                )
            seq = list(seq)
            return tuple(
                _stack([seq[g * period + s] for g in range(n_groups)])
                for s in range(period)
            )

        cols = {
            "params": by_slot(layers),
            "peft": by_slot(peft) if peft is not None else _EMPTY,
            "caches": by_slot(caches) if caches is not None else _EMPTY,
            "drops": drops.reshape(n_groups, period) if drops is not None else _EMPTY,
        }
        order = [k for k, v in cols.items() if v is not _EMPTY]
        xs = tuple(cols[k] for k in order)

        def gbody(h, xs_vals):
            v = dict(zip(order, xs_vals))
            aux_sum = jnp.zeros((), dtype=jnp.float32)
            out_caches = []
            for s in range(period):
                cache_l = v["caches"][s] if "caches" in v else None
                peft_l = v["peft"][s] if "peft" in v else None
                fn = lambda hh, cc, p=v["params"][s], pf=peft_l: block(p, pf, None, hh, cc)
                if "drops" in v:
                    h, aux, cache_l = stld.gate(fn, v["drops"][s], h, cache_l)
                else:
                    h, aux, cache_l = fn(h, cache_l)
                aux_sum = aux_sum + aux
                out_caches.append(cache_l if cache_l is not None else jnp.zeros((0,)))
            return h, (aux_sum, tuple(out_caches))

        h, (auxs, new_slot_caches) = jax.lax.scan(gbody, h, xs)
        aux_sum = jnp.sum(auxs)
        if caches is None:
            return h, aux_sum, None
        new_caches = []
        for g in range(n_groups):
            for s in range(period):
                new_caches.append(jax.tree.map(lambda x: x[g], new_slot_caches[s]))
        if stacking.is_stacked(caches):
            new_caches = stacking.stack_params(new_caches)
        return h, aux_sum, new_caches

    raise ValueError(f"unknown stack_mode {stack_mode!r}")


# --------------------------------------------------------------------------
# LM forward
# --------------------------------------------------------------------------
def lm_apply(
    params,
    cfg,
    tokens,
    *,
    positions=None,
    prefix_embeds=None,
    drops=None,
    caches=None,
    peft=None,
    lora_scale: float = 1.0,
    stack_mode: str = "unroll",
    active_idx=None,
    remat: bool = False,
):
    """Decoder-only LM forward.

    tokens: (B, S) int32.  ``prefix_embeds`` (B, P, d) is prepended (VLM stub
    frontend).  Returns (logits, aux, new_caches).
    """
    compute_dtype = jnp.dtype(cfg.dtype)
    h = params["embed"][tokens].astype(compute_dtype)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(compute_dtype), h], axis=1)
    if positions is None:
        positions = jnp.arange(h.shape[1])

    h, aux, new_caches = stack_apply(
        params["layers"],
        cfg,
        h,
        positions=positions,
        causal=True,
        drops=drops,
        caches=caches,
        peft=peft,
        lora_scale=lora_scale,
        stack_mode=stack_mode,
        active_idx=active_idx,
        remat=remat,
    )
    h = _norm_apply(cfg, params["final_norm"], h)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head.astype(compute_dtype)
    return logits, aux, new_caches
