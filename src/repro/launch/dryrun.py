import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract the roofline terms.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any jax import so 512 host platform
devices exist for ``jax.make_mesh``.

Per cell this:
  1. builds ShapeDtypeStruct inputs (no allocation, ``input_specs``),
  2. ``jax.jit(step, in_shardings=...).lower(...).compile()`` on the
     16x16 (single-pod) and 2x16x16 (multi-pod) meshes,
  3. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` (FLOPs / bytes) and the collective bytes parsed
     from the compiled HLO (all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute operand sizes),
  4. dumps one JSON per cell under ``results/dryrun/``.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import (  # noqa: E402
    ARCH_IDS,
    INPUT_SHAPES,
    PEFTConfig,
    TrainConfig,
    get_config,
    shape_applicable,
)
from repro.launch import input_specs as ispec  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step  # noqa: E402

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[1,2,3]' HLO shape string."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt, 4)
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the compiled HLO,
    per collective kind.  (Output shape == bytes moved per participant for
    AG/AR/A2A; a good first-order collective-traffic proxy.)"""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # match '<name> = <shape> <op>(' HLO lines, op like all-reduce(...)
        m = re.match(r"%?[\w\.\-]+\s*=\s*(\([^)]*\)|[\w\[\],]+)\s+([\w\-]+)", s)
        if not m:
            continue
        shape_str, op = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op.startswith(c):
                kind = c
                break
        if kind is None:
            continue
        # tuple shapes: sum every dtype[...] component
        total = 0
        if shape_str.startswith("("):
            for mm in _SHAPE_RE.finditer(shape_str):
                total += _shape_bytes(mm.group(0))
        else:
            total = _shape_bytes(shape_str)
        out[kind] += total
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, stld_mode: str = "off",
               stack_mode: str = "unroll", extra_tags: str = "",
               moe_dispatch: str = "einsum", weights_dtype: str = "float32",
               fsdp: bool = False, mean_rate: float = 0.5, expert_shard: str = "auto"):
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch).replace(moe_dispatch=moe_dispatch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    peft_cfg = PEFTConfig(method="lora", lora_rank=8)
    train_cfg = TrainConfig()

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            regather = None
            if fsdp:
                from jax.sharding import NamedSharding, PartitionSpec

                from repro.sharding import specs as sspecs

                sspecs.set_mesh_axis_sizes(mesh)
                base_shapes = ispec.eval_param_shapes(cfg)
                tp_specs = sspecs.param_specs(base_shapes, mesh.shape["model"])
                regather = jax.tree.map(
                    lambda s: NamedSharding(mesh, s),
                    tp_specs,
                    is_leaf=lambda x: isinstance(x, PartitionSpec),
                )
            step = make_train_step(
                cfg, peft_cfg, train_cfg, stld_mode=stld_mode,
                stack_mode=stack_mode, mean_rate=mean_rate,
                regather_specs=regather,
            )
            args, shardings = ispec.train_inputs(cfg, peft_cfg, shape, mesh, fsdp=fsdp)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, stack_mode=stack_mode)
            args, shardings = ispec.prefill_inputs(cfg, shape, mesh, weights_dtype=weights_dtype)
        else:
            step = make_serve_step(cfg, stack_mode=stack_mode)
            args, shardings = ispec.serve_inputs(
                cfg, shape, mesh, weights_dtype=weights_dtype, expert_shard=expert_shard
            )

        from jax.sharding import NamedSharding, PartitionSpec

        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            shardings,
            is_leaf=lambda x: isinstance(x, PartitionSpec),
        )
        lowered = jax.jit(step, in_shardings=shardings).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)

    n_chips = 1
    for v in dict(mesh.shape).values():
        n_chips *= v
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "stld_mode": stld_mode,
        "stack_mode": stack_mode,
        "tags": extra_tags,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
    }
    return record


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="input shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh (else 16x16)")
    ap.add_argument("--stld", default="off", choices=["off", "cond", "gather"])
    ap.add_argument(
        "--stack-mode",
        default="unroll",
        choices=["unroll", "scan", "group", "auto"],
        help="'auto' = group for hybrid archs, scan otherwise (fast compiles; "
        "used for the multi-pod pass where only lowering success matters)",
    )
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--moe-dispatch", default="einsum", choices=["einsum", "gather"])
    ap.add_argument("--weights-dtype", default="float32", choices=["float32", "bfloat16"])
    ap.add_argument("--fsdp", action="store_true", help="ZeRO-3-shard base params over data axes")
    ap.add_argument("--mean-rate", type=float, default=0.5, help="STLD mean dropout rate")
    ap.add_argument("--expert-shard", default="auto", choices=["auto", "ff"],
                    help="shard stacked expert weights on E (auto) or within-expert ff")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out_dir, exist_ok=True)

    for arch in archs:
        for shape_name in shapes:
            mesh_tag = "2x16x16" if args.multi_pod else "16x16"
            name = f"{arch}__{shape_name}__{mesh_tag}"
            if args.stld != "off":
                name += f"__stld-{args.stld}"
            if args.tag:
                name += f"__{args.tag}"
            out_path = os.path.join(args.out_dir, name + ".json")
            if not shape_applicable(arch, shape_name):
                rec = {
                    "arch": arch,
                    "shape": shape_name,
                    "mesh": mesh_tag,
                    "ok": False,
                    "skipped": True,
                    "reason": "long-context decode inapplicable (DESIGN.md skip matrix)",
                }
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=2)
                print(f"SKIP {name}")
                continue
            stack_mode = args.stack_mode
            if stack_mode == "auto":
                stack_mode = "group" if get_config(arch).family == "hybrid" else "scan"
            try:
                rec = lower_cell(
                    arch,
                    shape_name,
                    multi_pod=args.multi_pod,
                    stld_mode=args.stld,
                    stack_mode=stack_mode,
                    extra_tags=args.tag,
                    moe_dispatch=args.moe_dispatch,
                    weights_dtype=args.weights_dtype,
                    fsdp=args.fsdp,
                    mean_rate=args.mean_rate,
                    expert_shard=args.expert_shard,
                )
                print(
                    f"OK   {name}: flops={rec['flops']:.3e} "
                    f"bytes={rec['bytes_accessed']:.3e} "
                    f"coll={rec['collectives']['total']:.3e} "
                    f"peak/dev={rec['memory']['peak_bytes']/2**30:.2f}GiB "
                    f"compile={rec['compile_s']:.0f}s"
                )
            except Exception as e:  # noqa: BLE001 - record the failure
                rec = {
                    "arch": arch,
                    "shape": shape_name,
                    "mesh": mesh_tag,
                    "ok": False,
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                print(f"FAIL {name}: {type(e).__name__}: {e}")
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=2)


if __name__ == "__main__":
    main()
