"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape x step).

Nothing here allocates device memory: parameters, optimizer states, and KV
caches are built with ``jax.eval_shape`` over the real init functions, so the
dry-run lowers the exact production program.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape
from repro.core import peft as peft_lib
from repro.launch.mesh import data_axes
from repro.models import encdec
from repro.models.registry import init_params
from repro.models.transformer import init_caches
from repro.optim import adamw_init
from repro.sharding import specs as sharding_specs


def _struct(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def eval_param_shapes(cfg):
    return jax.eval_shape(partial(init_params, cfg=cfg), jax.random.PRNGKey(0))


def eval_peft_shapes(cfg, peft_cfg):
    return jax.eval_shape(partial(peft_lib.init_peft, cfg=cfg, peft_cfg=peft_cfg), jax.random.PRNGKey(0))


def eval_cache_shapes(cfg, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_len))


def _modality_extras(cfg, batch: int):
    extras = {}
    if cfg.modality == "vision":
        extras["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.modality == "audio":
        extras["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return extras


def _batch_axes_size(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def train_inputs(cfg, peft_cfg, shape: InputShape, mesh, *, fsdp: bool = False) -> Tuple[tuple, tuple]:
    """(arg structs, in_shardings specs) for ``train_step``."""
    sharding_specs.set_mesh_axis_sizes(mesh)
    tp = mesh.shape["model"]
    b_axes = data_axes(mesh)

    base = eval_param_shapes(cfg)
    peft = eval_peft_shapes(cfg, peft_cfg)
    opt = jax.eval_shape(adamw_init, peft)
    batch = {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len + 1), jnp.int32),
        **_modality_extras(cfg, shape.global_batch),
    }
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)

    base_s = sharding_specs.param_specs(base, tp, fsdp_axes=b_axes if fsdp else ())
    peft_s = sharding_specs.peft_specs(peft)
    opt_s = {"m": peft_s, "v": peft_s, "count": P()}
    bspec = sharding_specs.batch_spec(b_axes, 2)
    batch_s = {k: (bspec if v.ndim == 2 else sharding_specs.batch_spec(b_axes, v.ndim)) for k, v in batch.items()}
    rng_s = P()
    args = (base, peft, opt, batch, rng)
    shardings = (base_s, peft_s, opt_s, batch_s, rng_s)
    return args, shardings


def _cast_params(params, dtype):
    """Serving weights dtype (bf16 deployment: halves resident bytes)."""
    import numpy as np

    def cast(x):
        if np.issubdtype(x.dtype, np.floating):
            return jax.ShapeDtypeStruct(x.shape, jnp.dtype(dtype))
        return x

    return jax.tree.map(cast, params)


def prefill_inputs(cfg, shape: InputShape, mesh, *, weights_dtype: str = "float32") -> Tuple[tuple, tuple]:
    sharding_specs.set_mesh_axis_sizes(mesh)
    tp = mesh.shape["model"]
    b_axes = data_axes(mesh)
    b = shape.global_batch

    cache_len = shape.seq_len + (cfg.frontend_seq if cfg.modality == "vision" else 0)
    params = _cast_params(eval_param_shapes(cfg), weights_dtype)
    caches = eval_cache_shapes(cfg, b, cache_len)
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
        **_modality_extras(cfg, b),
    }
    params_s = sharding_specs.param_specs(params, tp)
    caches_s = sharding_specs.cache_specs(caches, b_axes, tp)
    batch_s = {
        k: sharding_specs.batch_spec(b_axes, v.ndim) for k, v in batch.items()
    }
    return (params, batch, caches), (params_s, batch_s, caches_s)


def serve_inputs(cfg, shape: InputShape, mesh, *, weights_dtype: str = "float32", expert_shard: str = "auto") -> Tuple[tuple, tuple]:
    """Decode: ONE new token against a cache of ``seq_len``."""
    sharding_specs.set_mesh_axis_sizes(mesh)
    tp = mesh.shape["model"]
    b_axes = data_axes(mesh)
    b = shape.global_batch
    n_data = _batch_axes_size(mesh)
    shard_seq = b < n_data  # long_500k: B=1 -> sequence-shard the cache

    # SWA archs hold only a window-sized ring buffer (init_layer_cache caps);
    # VLM caches cover the patch prefix too
    cache_len = shape.seq_len + (cfg.frontend_seq if cfg.modality == "vision" else 0)
    params = _cast_params(eval_param_shapes(cfg), weights_dtype)
    caches = eval_cache_shapes(cfg, b, cache_len)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    params_s = sharding_specs.param_specs(params, tp, expert_shard=expert_shard)
    caches_s = sharding_specs.cache_specs(
        caches, b_axes, tp, shard_seq_on_data=shard_seq
    )
    token_s = sharding_specs.batch_spec(b_axes, 2) if not shard_seq else P()
    args = [params, token, pos, caches]
    shardings = [params_s, token_s, P(), caches_s]

    if cfg.is_encoder_decoder:
        enc_kvs = jax.eval_shape(
            lambda p, e: encdec.encoder_cross_kvs(p, cfg, e),
            params,
            jax.ShapeDtypeStruct((b, cfg.frontend_seq, cfg.d_model), jnp.dtype(cfg.dtype)),
        )
        args.append(enc_kvs)
        shardings.append(sharding_specs.cache_specs(enc_kvs, b_axes, tp))
    return tuple(args), tuple(shardings)
