"""Production step functions (train / prefill / serve) used by the drivers
and lowered by the multi-pod dry-run.

``stld`` argument selects the paper semantics:
  * ``off``    — plain federated PEFT (FedLoRA/FedAdapter baseline compute)
  * ``cond``   — paper-faithful STLD: traced ``lax.cond`` gates (runtime skip)
  * ``gather`` — TPU-native gather-STLD: the compiled graph itself shrinks
                 to the static active-layer count (DESIGN.md §2)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import peft as peft_lib
from repro.core import stld
from repro.core.schedules import unit_shape
from repro.models.losses import softmax_xent
from repro.models.registry import model_apply
from repro.models import encdec
from repro.optim import adamw_update, clip_by_global_norm


def make_train_step(
    cfg,
    peft_cfg,
    train_cfg,
    *,
    stld_mode: str = "off",
    mean_rate: float = 0.5,
    distribution: str = "incremental",
    stack_mode: str = "unroll",
    gather_bucket: int = 4,
    remat: bool = False,
    regather_specs=None,
):
    """Next-token LM fine-tuning step over the PEFT params.

    signature: (base_params, peft_params, opt_state, batch, rng)
      batch = {"tokens": (B, S+1) int32 [, "patches" | "frames"]}
    returns (peft_params, opt_state, metrics)
    """
    lora_sc = peft_lib.lora_scale(peft_cfg) if peft_cfg.method == "lora" else 1.0
    shape_vec = None
    num_active = None
    if stld_mode != "off":
        shape_vec = unit_shape(distribution, cfg.num_layers)
        if stld_mode == "gather":
            num_active = stld.static_active_count(
                mean_rate, cfg.num_layers, gather_bucket
            )

    def loss_fn(peft_params, base_params, batch, drops, active_idx):
        tokens = batch["tokens"]
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        model_batch = dict(batch, tokens=inputs)
        logits, aux, _ = model_apply(
            base_params,
            cfg,
            model_batch,
            drops=drops,
            peft=peft_params,
            lora_scale=lora_sc,
            stack_mode=(
                ("gather_unroll" if stack_mode == "unroll" else "gather")
                if active_idx is not None
                else stack_mode
            ),
            active_idx=active_idx,
            remat=remat,
        )
        if cfg.modality == "vision":  # strip stub-frontend prefix positions
            logits = logits[:, -inputs.shape[1] :]
        loss, metrics = softmax_xent(logits, targets)
        return loss + cfg.router_aux_coef * aux, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(base_params, peft_params, opt_state, batch, rng):
        if regather_specs is not None:
            # FSDP: base params arrive ZeRO-3-sharded over the data axes;
            # all-gather them ONCE per step back to TP-only layout so every
            # downstream einsum keeps its clean tensor-parallel sharding
            # (leaving it to GSPMD propagation replicates MoE compute).
            base_params = jax.lax.with_sharding_constraint(base_params, regather_specs)
        drops = active_idx = None
        if stld_mode == "cond":
            rates = jnp.clip(shape_vec * mean_rate, 0.0, 0.95)
            drops = stld.sample_drops(rng, rates, 1)
        elif stld_mode == "gather":
            rates = jnp.clip(shape_vec * mean_rate, 0.0, 0.95)
            active_idx = stld.sample_active_indices(rng, rates, num_active)
        (loss, metrics), grads = grad_fn(peft_params, base_params, batch, drops, active_idx)
        grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        peft_params, opt_state = adamw_update(
            grads,
            opt_state,
            peft_params,
            lr=train_cfg.learning_rate,
            beta1=train_cfg.beta1,
            beta2=train_cfg.beta2,
            eps=train_cfg.eps,
            weight_decay=train_cfg.weight_decay,
        )
        metrics = dict(metrics, grad_norm=gnorm)
        return peft_params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, *, stack_mode: str = "unroll"):
    """(params, batch, caches) -> (last_logits, caches).

    batch: {"tokens": (B, S) [, "patches" | "frames"]}.
    """

    def prefill_step(params, batch, caches):
        kw = {}
        if cfg.is_encoder_decoder:
            enc_out = encdec.encode(params, cfg, batch["frames"], stack_mode=stack_mode)
            enc_kvs = encdec.encoder_cross_kvs(params, cfg, enc_out)
            logits, _, caches = encdec.decode(
                params,
                cfg,
                batch["tokens"],
                enc_kvs,
                caches=caches,
                stack_mode=stack_mode,
            )
            return logits[:, -1], caches, enc_kvs
        logits, _, caches = model_apply(
            params, cfg, batch, caches=caches, stack_mode=stack_mode, **kw
        )
        return logits[:, -1], caches

    return prefill_step


def make_serve_step(cfg, *, stack_mode: str = "unroll"):
    """Single-token decode against a KV cache.

    (params, token (B,1), pos (), caches [, enc_kvs]) ->
        (logits (B, V), next_token (B, 1), caches)

    ``pos`` may also be a ``(B,)`` vector — the multi-tenant serving path,
    where continuous batching runs every row at its own depth — and ``peft``
    an adapter tree (e.g. per-projection :class:`~repro.nn.linear.AdapterPool`
    nodes) applied during decode.  Pass ``peft`` as a traced argument, not a
    closure constant, so adapter hot-swaps reuse the compiled step.
    """

    def serve_step(params, token, pos, caches, enc_kvs=None, peft=None):
        positions = pos[..., None] + jnp.arange(1)  # () -> (1,); (B,) -> (B,1)
        if jnp.ndim(pos) == 0:
            positions = positions.reshape(1)
        batch = {"tokens": token}
        if cfg.is_encoder_decoder:
            logits, _, caches = encdec.decode(
                params,
                cfg,
                token,
                enc_kvs,
                positions=positions,
                caches=caches,
                peft=peft,
                stack_mode=stack_mode,
            )
        else:
            logits, _, caches = model_apply(
                params,
                cfg,
                batch,
                positions=positions,
                caches=caches,
                peft=peft,
                stack_mode=stack_mode,
            )
        logits = logits[:, -1]
        next_token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return logits, next_token, caches

    return serve_step
