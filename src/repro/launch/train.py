"""End-to-end federated fine-tuning driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --method droppeft --rounds 20 --peft lora

Runs the full DropPEFT system — STLD local fine-tuning, bandit dropout-rate
configurator, PTLS aggregation — over the synthetic federated task through
the ``repro.api`` facade, with checkpointing and a round-by-round report.
``--smoke`` selects the reduced per-arch config (CPU-runnable); without it
the assigned full config is used (TPU-scale — pair with the production
mesh).  ``--resume`` continues bit-exactly from the newest run-state
checkpoint under ``--state-dir``.  ``--schedule`` selects the
virtual-clock scheduling policy (``sync`` barrier, ``deadline`` with
``--deadline``/``--straggler``, FedBuff-style ``async-buffer`` with
``--buffer-size``/``--staleness-alpha``).  ``--fault-plan`` (a JSON
:class:`~repro.federated.faults.FaultPlan` file) or the ``--fault-*``
shorthand probabilities inject seeded client dropout / bandwidth collapse /
NaN updates; rejected updates and retries land in the report.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import replace as dc_replace

from repro import api
from repro.federated.faults import FaultPlan
from repro.checkpoint import save_pytree
from repro.configs import (
    ARCH_IDS,
    FederatedConfig,
    PEFTConfig,
    STLDConfig,
    TrainConfig,
    get_config,
)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--method", default="droppeft", choices=api.list_methods())
    ap.add_argument("--peft", default="lora", choices=["lora", "adapter", "bitfit"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--devices", type=int, default=16)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--alpha", type=float, default=1.0, help="Dirichlet non-IIDness")
    ap.add_argument("--stld-mode", default="cond", choices=["cond", "gather"])
    ap.add_argument("--schedule", default=None,
                    choices=["sync", "deadline", "async-buffer"],
                    help="virtual-clock scheduling policy (default sync; "
                    "--deadline/--straggler imply deadline, --buffer-size "
                    "implies async-buffer)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="round budget in virtual seconds (deadline policy)")
    ap.add_argument("--straggler", default=None, choices=["drop", "carry"],
                    help="what happens to updates that miss the deadline "
                    "(default drop)")
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="async-buffer: aggregate every K arrivals")
    ap.add_argument("--staleness-alpha", type=float, default=None,
                    help="staleness discount exponent: w = 1/(1+s)^alpha")
    ap.add_argument("--compression", default=None,
                    choices=["none", "int8", "topk", "int8+topk", "auto"],
                    help="uplink delta compression; 'auto' lets the joint "
                    "bandit pick (dropout rate x level) arms; omit for the "
                    "bit-exact uncompressed path")
    ap.add_argument("--topk-fraction", type=float, default=None,
                    help="fraction of entries top-k sparsification keeps "
                    "per leaf (default 0.1)")
    ap.add_argument("--fault-plan", default=None,
                    help="JSON FaultPlan file (repro.federated.faults); the "
                    "--fault-* flags override its fields")
    ap.add_argument("--fault-dropout", type=float, default=None,
                    help="per-job client mid-round dropout probability")
    ap.add_argument("--fault-nan", type=float, default=None,
                    help="per-job corrupted (NaN) update probability")
    ap.add_argument("--fault-bandwidth", type=float, default=None,
                    help="per-job bandwidth-collapse probability")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="fault-plan RNG seed (default: --seed)")
    ap.add_argument("--mean-rate", type=float, default=0.5)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--target-acc", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="results/checkpoints")
    ap.add_argument("--state-dir", default=None,
                    help="save resumable run state each round to this dir")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest run-state checkpoint")
    ap.add_argument("--out", default="results/train_history.json")
    args = ap.parse_args()

    fault_kw = {
        k: v
        for k, v in (
            ("dropout_prob", args.fault_dropout),
            ("nan_update_prob", args.fault_nan),
            ("bandwidth_collapse_prob", args.fault_bandwidth),
            ("seed", args.fault_seed),
        )
        if v is not None
    }
    if args.fault_plan:
        fault_plan = FaultPlan.from_file(args.fault_plan)
        if fault_kw:
            fault_plan = dc_replace(fault_plan, **fault_kw)
    elif fault_kw:
        fault_kw.setdefault("seed", args.seed)
        fault_plan = FaultPlan(**fault_kw)
    else:
        fault_plan = None

    cfg = get_config(args.arch, smoke=args.smoke)
    fed_cfg = FederatedConfig(
        num_devices=args.devices,
        devices_per_round=args.cohort,
        local_steps=args.local_steps,
        batch_size=args.batch_size,
        rounds=args.rounds,
        dirichlet_alpha=args.alpha,
        seed=args.seed,
    )

    print(f"== DropPEFT federated fine-tuning: {cfg.name} ({args.method}, {args.peft}) ==")
    t0 = time.time()
    runner = api.build(
        args.method,
        cfg=cfg,
        peft_cfg=PEFTConfig(method=args.peft),
        stld_cfg=STLDConfig(mode=args.stld_mode, mean_rate=args.mean_rate),
        fed_cfg=fed_cfg,
        train_cfg=TrainConfig(
            learning_rate=args.lr, total_steps=args.rounds * args.local_steps
        ),
        cost_model=args.arch,
        seed=args.seed,
        schedule=args.schedule,
        deadline_s=args.deadline,
        straggler=args.straggler,
        buffer_size=args.buffer_size,
        staleness_alpha=args.staleness_alpha,
        compression=args.compression,
        topk_fraction=args.topk_fraction,
        checkpoint_dir=args.state_dir,
        resume=args.resume,
        fault_plan=fault_plan,
    )
    res = runner.run(rounds=args.rounds, target_accuracy=args.target_acc)

    for r in range(res.rounds):
        print(
            f"round {r:3d}  acc={res.accuracy[r]:.3f} loss={res.loss[r]:.3f} "
            f"rate={res.rates[r]:.2f} active={res.active_fraction[r]:.2f} "
            f"t={res.cum_time_s[r]/3600:.2f}h mem={res.memory_gb[r]:.1f}GB"
        )
    print(f"final accuracy (all devices): {res.final_accuracy:.3f}")
    if fault_plan is not None:
        rejected = [
            e for e in runner.scheduler.fault_log
            if e["reason"] in ("dropout", "non-finite-update")
        ]
        print(
            f"faults: {len(runner.scheduler.fault_log)} events, "
            f"{len(rejected)} rejected updates "
            f"({sum(e['burned_compute_s'] for e in rejected):.0f}s compute burned)"
        )
    print(f"wall time: {time.time()-t0:.1f}s (simulated federated: {res.cum_time_s[-1]/3600:.2f}h)")

    os.makedirs(args.ckpt_dir, exist_ok=True)
    save_pytree(runner.state.global_peft, os.path.join(args.ckpt_dir, cfg.name), res.rounds)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(
            {
                "arch": cfg.name,
                "method": args.method,
                "schedule": runner.schedule.policy,
                "compression": args.compression,
                "accuracy": res.accuracy.tolist(),
                "cum_time_s": res.cum_time_s.tolist(),
                "final_accuracy": res.final_accuracy,
                "traffic_mb": res.traffic_mb.tolist(),
                "energy_j": res.energy_j.tolist(),
                "fault_log": runner.scheduler.fault_log,
            },
            f,
            indent=2,
        )
    print(f"history -> {args.out}")


if __name__ == "__main__":
    main()
