"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax
initialises devices.

Target hardware: TPU v5e pods; single pod = 16x16 = 256 chips
(data x model), multi-pod = 2 x 16 x 16 = 512 chips (pod x data x model).
In the federated mapping (DESIGN.md §7) the ``pod``+``data`` axes carry the
client cohort / per-client batch; ``model`` carries tensor/expert parallel.
"""
from __future__ import annotations

import jax

# TPU v5e per-chip constants used by the roofline (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW_PER_LINK = 50e9         # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1x1 mesh over the real local device (CPU smoke tests, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Mesh axes that carry the batch/cohort dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
