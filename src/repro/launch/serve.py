"""Serving driver: prefill a prompt batch, then greedy-decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --prompt-len 64 --gen-len 32 --batch 4

Exercises the full serving path (prefill_step -> serve_step loop) for any
assigned architecture, including recurrent-state archs and the whisper
encoder-decoder.  With ``--merge-lora`` a trained LoRA checkpoint is folded
into the base weights first (deployment path).

Multi-tenant mode — ``--adapters N`` serves N tenants' LoRA adapters
(mixed hetlora ranks) through the continuous batcher and the segmented
gather kernel, one compiled decode step for the whole mix::

    PYTHONPATH=src python -m repro.launch.serve --smoke --adapters 3 \
        --batch 4 --gen-len 16
    PYTHONPATH=src python -m repro.launch.serve --smoke \
        --checkpoint-dir ckpts --batch 4    # federated client adapters
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, PEFTConfig, get_config
from repro.core import peft as peft_lib
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models.registry import init_params
from repro.models.transformer import init_caches
from repro.serving.decode import generate


def _serve_multi_adapter(cfg, params, key, args):
    """Continuous-batching decode over per-tenant adapters."""
    from repro import api
    from repro.serving.batcher import Request

    adapters = None
    if args.checkpoint_dir is None:
        # synthetic tenants with alternating hetlora ranks
        adapters = {}
        for i in range(args.adapters):
            rank = (4, 8)[i % 2]
            pcfg = PEFTConfig(method="lora", lora_rank=rank, lora_targets=("q", "v"))
            tree = peft_lib.init_peft(jax.random.fold_in(key, 100 + i), cfg, pcfg)
            adapters[f"tenant{i}"] = tree
    batcher = api.serve(
        cfg=cfg,
        params=params,
        checkpoint_dir=args.checkpoint_dir,
        adapters=adapters,
        batch=args.batch,
        max_len=args.prompt_len + args.gen_len,
        cache_dtype=cfg.dtype,
    )
    names = batcher.pool.registry.names()
    for j in range(max(args.batch, len(names))):
        prompt = jax.random.randint(
            jax.random.fold_in(key, j), (args.prompt_len,), 0, cfg.vocab_size
        )
        batcher.submit(
            Request(
                prompt=prompt.tolist(),
                adapter=names[j % len(names)],
                max_new_tokens=args.gen_len,
                uid=j,
            )
        )
    t0 = time.time()
    done = batcher.run()
    dt = time.time() - t0
    total = sum(len(c.tokens) for c in done)
    print(f"arch={cfg.name} tenants={len(names)} requests={len(done)} "
          f"slots={batcher.pool.n_slots} swaps={batcher.pool.swaps}")
    print(f"decode: {dt*1e3:.1f} ms ({total/max(dt,1e-9):.1f} tok/s)")
    for c in done[: args.batch]:
        print(f"  req {c.uid} [{c.adapter}] {c.finish_reason}: {c.tokens[:8]}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--merge-lora", action="store_true")
    ap.add_argument("--adapters", type=int, default=0,
                    help="serve N synthetic tenant adapters (multi-tenant mode)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="serve the client adapters of a federated checkpoint")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)

    if args.adapters > 0 or args.checkpoint_dir is not None:
        _serve_multi_adapter(cfg, params, key, args)
        return

    if args.merge_lora:
        peft_cfg = PEFTConfig(method="lora")
        peft_tree = peft_lib.init_peft(jax.random.fold_in(key, 1), cfg, peft_cfg)
        params = dict(params, layers=peft_lib.merge_lora_into_base(
            params["layers"], peft_tree, peft_lib.lora_scale(peft_cfg)))
        print("merged LoRA into base weights")

    stack_mode = "unroll"
    max_len = args.prompt_len + args.gen_len
    if cfg.modality == "vision":
        max_len += cfg.frontend_seq  # cache also holds the patch prefix
    prefill = jax.jit(make_prefill_step(cfg, stack_mode=stack_mode))
    serve = jax.jit(make_serve_step(cfg, stack_mode=stack_mode))

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompt}
    if cfg.modality == "vision":
        batch["patches"] = jnp.zeros((args.batch, cfg.frontend_seq, cfg.d_model), cfg.dtype)
    if cfg.modality == "audio":
        batch["frames"] = jnp.zeros((args.batch, cfg.frontend_seq, cfg.d_model), cfg.dtype)

    caches = init_caches(cfg, args.batch, max_len, dtype=jnp.dtype(cfg.dtype))
    t0 = time.time()
    enc_kvs = None
    if cfg.is_encoder_decoder:
        last_logits, caches, enc_kvs = prefill(params, batch, caches)
    else:
        last_logits, caches = prefill(params, batch, caches)
    t_prefill = time.time() - t0
    first = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)

    start_pos = args.prompt_len + (cfg.frontend_seq if cfg.modality == "vision" else 0)
    t0 = time.time()
    toks, caches = generate(serve, params, caches, first, start_pos, args.gen_len, enc_kvs=enc_kvs)
    toks.block_until_ready()
    t_decode = time.time() - t0

    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: {t_decode*1e3:.1f} ms "
          f"({args.gen_len*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample tokens:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
