from repro.checkpoint.ckpt import (
    latest_state_dir,
    load_pytree,
    load_state,
    restore_latest,
    save_pytree,
    save_state,
)

__all__ = [
    "save_pytree",
    "load_pytree",
    "restore_latest",
    "save_state",
    "load_state",
    "latest_state_dir",
]
