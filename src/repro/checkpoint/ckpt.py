"""Pytree checkpointing: npz payload + json manifest (no external deps).

Layout: ``<dir>/step_<n>/manifest.json`` + ``arrays.npz``.  Leaves are
addressed by their flattened key-path string, so any nested dict/list/tuple
pytree round-trips exactly (structure + dtypes + shapes).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: Any, directory: str, step: int) -> str:
    out_dir = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(out_dir, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        key = f"leaf_{i}"
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":  # numpy npz cannot hold bf16: store bits
            arr = arr.view(np.uint16)
        arrays[key] = arr
        manifest["leaves"].append({"key": key, "path": _path_str(path), "dtype": dtype_name})
    treedef = jax.tree.structure(tree)
    manifest["treedef"] = str(treedef)
    np.savez(os.path.join(out_dir, "arrays.npz"), **arrays)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return out_dir


def load_pytree(template: Any, checkpoint_dir: str) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    with open(os.path.join(checkpoint_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(checkpoint_dir, "arrays.npz"))
    import ml_dtypes

    leaves = []
    for entry in manifest["leaves"]:
        arr = data[entry["key"]]
        if entry["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        leaves.append(arr)
    treedef = jax.tree.structure(template)
    restored = jax.tree.unflatten(treedef, leaves)
    # preserve template dtypes (e.g. bf16 params stored as their numpy repr)
    return jax.tree.map(lambda t, r: jax.numpy.asarray(r, dtype=t.dtype), template, restored)


def restore_latest(template: Any, directory: str) -> Optional[tuple]:
    """(tree, step) from the newest ``step_*`` subdir, or None."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    if not steps:
        return None
    step = max(steps)
    tree = load_pytree(template, os.path.join(directory, f"step_{step:08d}"))
    return tree, step


# --------------------------------------------------------------------------
# Templateless state checkpoints (experiment save/resume).
#
# ``save_pytree``/``load_pytree`` need a live template to rebuild structure,
# which a resuming process does not have for run state whose shape depends on
# history (e.g. which federated devices have participated).  ``save_state``
# therefore records an explicit JSON skeleton of the container structure
# (dict/list/tuple) alongside the leaf arrays, plus an arbitrary JSON
# ``meta`` payload for host-side state (RNG states, counters, histories).


def _skeletonize(node: Any, leaves: list):
    if isinstance(node, dict):
        keys = list(node.keys())
        return {"t": "dict", "k": keys, "v": [_skeletonize(node[k], leaves) for k in keys]}
    if isinstance(node, (list, tuple)):
        return {
            "t": "list" if isinstance(node, list) else "tuple",
            "v": [_skeletonize(x, leaves) for x in node],
        }
    arr = np.asarray(node)
    dtype_name = str(arr.dtype)
    if dtype_name == "bfloat16":  # numpy npz cannot hold bf16: store bits
        arr = arr.view(np.uint16)
    leaves.append(arr)
    return {"t": "leaf", "i": len(leaves) - 1, "dtype": dtype_name}


def _unskeletonize(skel: dict, data) -> Any:
    kind = skel["t"]
    if kind == "dict":
        return {
            k: _unskeletonize(v, data) for k, v in zip(skel["k"], skel["v"])
        }
    if kind in ("list", "tuple"):
        items = [_unskeletonize(v, data) for v in skel["v"]]
        return items if kind == "list" else tuple(items)
    arr = data[f"leaf_{skel['i']}"]
    if skel["dtype"] == "bfloat16":
        import ml_dtypes

        arr = arr.view(ml_dtypes.bfloat16)
    return arr


def save_state(directory: str, step: int, tree: Any, meta: Any = None) -> str:
    """Save a nested dict/list/tuple of arrays + a JSON ``meta`` payload."""
    out_dir = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(out_dir, exist_ok=True)
    leaves: list = []
    skeleton = _skeletonize(tree, leaves)
    np.savez(
        os.path.join(out_dir, "arrays.npz"),
        **{f"leaf_{i}": arr for i, arr in enumerate(leaves)},
    )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"step": step, "skeleton": skeleton, "meta": meta}, f, indent=2)
    return out_dir


def load_state(checkpoint_dir: str) -> tuple:
    """(tree, meta) saved by :func:`save_state`."""
    with open(os.path.join(checkpoint_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(checkpoint_dir, "arrays.npz"))
    return _unskeletonize(manifest["skeleton"], data), manifest.get("meta")


def latest_state_dir(directory: str) -> Optional[str]:
    """Path of the newest ``step_*`` checkpoint under ``directory``, or None."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    if not steps:
        return None
    return os.path.join(directory, f"step_{max(steps):08d}")
