"""Pytree checkpointing: npz payload + json manifest (no external deps).

Layout: ``<dir>/step_<n>/manifest.json`` + ``arrays.npz``.  Leaves are
addressed by their flattened key-path string, so any nested dict/list/tuple
pytree round-trips exactly (structure + dtypes + shapes).

Writes are atomic: each snapshot is staged in a ``.tmp-`` sibling directory
and renamed into place with ``os.replace`` only after every file landed, so
a crash mid-save leaves either the previous complete snapshot or a stale
temp dir — never a half-written ``step_*``.  Readers
(:func:`latest_state_dir` / :func:`restore_latest`) additionally validate
each candidate and fall back to the newest *complete* snapshot, so even a
torn directory produced by a pre-atomic writer (or a filesystem that lost
the rename) cannot poison resume.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _commit_dir(directory: str, step: int, write_files) -> str:
    """Atomically materialize ``<directory>/step_<step>``.

    ``write_files(tmp_dir)`` stages every file; the staged dir is then
    renamed over the final path.  ``os.replace`` cannot overwrite a
    non-empty directory, so an existing snapshot for the same step is
    removed first — worst case a crash between the two calls loses only
    that one step and resume falls back to the previous snapshot.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f".tmp-step_{step:08d}")
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    write_files(tmp)
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def _snapshot_ok(path: str) -> bool:
    """True when ``path`` holds a complete, loadable snapshot: the manifest
    parses and the npz central directory is intact (a truncated write fails
    both cheaply, without loading array payloads)."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as data:
            data.files  # noqa: B018 — forces the zip directory read
        return True
    except Exception:
        return False


def _complete_steps(directory: str):
    """Step numbers under ``directory`` whose snapshots validate, ascending
    (partial/corrupt dirs are skipped, not fatal)."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and _snapshot_ok(os.path.join(directory, name)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: Any, directory: str, step: int) -> str:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        key = f"leaf_{i}"
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":  # numpy npz cannot hold bf16: store bits
            arr = arr.view(np.uint16)
        arrays[key] = arr
        manifest["leaves"].append({"key": key, "path": _path_str(path), "dtype": dtype_name})
    treedef = jax.tree.structure(tree)
    manifest["treedef"] = str(treedef)

    def write(tmp_dir):
        np.savez(os.path.join(tmp_dir, "arrays.npz"), **arrays)
        with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)

    return _commit_dir(directory, step, write)


def load_pytree(template: Any, checkpoint_dir: str) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    with open(os.path.join(checkpoint_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(checkpoint_dir, "arrays.npz"))
    import ml_dtypes

    leaves = []
    for entry in manifest["leaves"]:
        arr = data[entry["key"]]
        if entry["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        leaves.append(arr)
    treedef = jax.tree.structure(template)
    restored = jax.tree.unflatten(treedef, leaves)
    # preserve template dtypes (e.g. bf16 params stored as their numpy repr)
    return jax.tree.map(lambda t, r: jax.numpy.asarray(r, dtype=t.dtype), template, restored)


def restore_latest(template: Any, directory: str) -> Optional[tuple]:
    """(tree, step) from the newest *complete* ``step_*`` subdir, or None.

    Partial or corrupt snapshots (crash mid-save before atomic writes, torn
    copies) are skipped, so restore degrades to the previous good step
    instead of raising on a broken newest one."""
    steps = _complete_steps(directory)
    if not steps:
        return None
    step = steps[-1]
    tree = load_pytree(template, os.path.join(directory, f"step_{step:08d}"))
    return tree, step


# --------------------------------------------------------------------------
# Templateless state checkpoints (experiment save/resume).
#
# ``save_pytree``/``load_pytree`` need a live template to rebuild structure,
# which a resuming process does not have for run state whose shape depends on
# history (e.g. which federated devices have participated).  ``save_state``
# therefore records an explicit JSON skeleton of the container structure
# (dict/list/tuple) alongside the leaf arrays, plus an arbitrary JSON
# ``meta`` payload for host-side state (RNG states, counters, histories).


def _skeletonize(node: Any, leaves: list):
    if isinstance(node, dict):
        keys = list(node.keys())
        return {"t": "dict", "k": keys, "v": [_skeletonize(node[k], leaves) for k in keys]}
    if isinstance(node, (list, tuple)):
        return {
            "t": "list" if isinstance(node, list) else "tuple",
            "v": [_skeletonize(x, leaves) for x in node],
        }
    arr = np.asarray(node)
    dtype_name = str(arr.dtype)
    if dtype_name == "bfloat16":  # numpy npz cannot hold bf16: store bits
        arr = arr.view(np.uint16)
    leaves.append(arr)
    return {"t": "leaf", "i": len(leaves) - 1, "dtype": dtype_name}


def _unskeletonize(skel: dict, data) -> Any:
    kind = skel["t"]
    if kind == "dict":
        return {
            k: _unskeletonize(v, data) for k, v in zip(skel["k"], skel["v"])
        }
    if kind in ("list", "tuple"):
        items = [_unskeletonize(v, data) for v in skel["v"]]
        return items if kind == "list" else tuple(items)
    arr = data[f"leaf_{skel['i']}"]
    if skel["dtype"] == "bfloat16":
        import ml_dtypes

        arr = arr.view(ml_dtypes.bfloat16)
    return arr


def save_state(directory: str, step: int, tree: Any, meta: Any = None) -> str:
    """Save a nested dict/list/tuple of arrays + a JSON ``meta`` payload."""
    leaves: list = []
    skeleton = _skeletonize(tree, leaves)

    def write(tmp_dir):
        np.savez(
            os.path.join(tmp_dir, "arrays.npz"),
            **{f"leaf_{i}": arr for i, arr in enumerate(leaves)},
        )
        with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
            json.dump({"step": step, "skeleton": skeleton, "meta": meta}, f, indent=2)

    return _commit_dir(directory, step, write)


def load_state(checkpoint_dir: str) -> tuple:
    """(tree, meta) saved by :func:`save_state`."""
    with open(os.path.join(checkpoint_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(checkpoint_dir, "arrays.npz"))
    return _unskeletonize(manifest["skeleton"], data), manifest.get("meta")


def latest_state_dir(directory: str) -> Optional[str]:
    """Path of the newest *complete* ``step_*`` checkpoint under
    ``directory``, or None.  A truncated or corrupt newest snapshot (crash
    mid-save) is skipped in favor of the previous valid one."""
    steps = _complete_steps(directory)
    if not steps:
        return None
    return os.path.join(directory, f"step_{steps[-1]:08d}")
