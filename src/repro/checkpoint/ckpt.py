"""Pytree checkpointing: npz payload + json manifest (no external deps).

Layout: ``<dir>/step_<n>/manifest.json`` + ``arrays.npz``.  Leaves are
addressed by their flattened key-path string, so any nested dict/list/tuple
pytree round-trips exactly (structure + dtypes + shapes).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: Any, directory: str, step: int) -> str:
    out_dir = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(out_dir, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        key = f"leaf_{i}"
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":  # numpy npz cannot hold bf16: store bits
            arr = arr.view(np.uint16)
        arrays[key] = arr
        manifest["leaves"].append({"key": key, "path": _path_str(path), "dtype": dtype_name})
    treedef = jax.tree.structure(tree)
    manifest["treedef"] = str(treedef)
    np.savez(os.path.join(out_dir, "arrays.npz"), **arrays)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return out_dir


def load_pytree(template: Any, checkpoint_dir: str) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    with open(os.path.join(checkpoint_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(checkpoint_dir, "arrays.npz"))
    import ml_dtypes

    leaves = []
    for entry in manifest["leaves"]:
        arr = data[entry["key"]]
        if entry["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        leaves.append(arr)
    treedef = jax.tree.structure(template)
    restored = jax.tree.unflatten(treedef, leaves)
    # preserve template dtypes (e.g. bf16 params stored as their numpy repr)
    return jax.tree.map(lambda t, r: jax.numpy.asarray(r, dtype=t.dtype), template, restored)


def restore_latest(template: Any, directory: str) -> Optional[tuple]:
    """(tree, step) from the newest ``step_*`` subdir, or None."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    if not steps:
        return None
    step = max(steps)
    tree = load_pytree(template, os.path.join(directory, f"step_{step:08d}"))
    return tree, step
