"""The online dropout-rate configurator (paper Algorithm 1) in isolation.

Simulates an environment where reward = accuracy-gain/time peaks at a
"sweet spot" dropout rate that DRIFTS over time (paper Fig. 7), and shows
the bandit tracking it.

    PYTHONPATH=src python examples/bandit_configurator.py
"""
import numpy as np

from repro import api
from repro.configs import FederatedConfig

rng = np.random.default_rng(0)
# the exact configurator a DropPEFT experiment would use: built by the
# algorithm from the federated config, pulled out of the runner's RoundState
runner = api.build(
    "droppeft",
    model_overrides=dict(num_layers=4, d_model=32, d_ff=64, num_heads=2,
                         num_kv_heads=2, vocab_size=128, dtype="float32"),
    lora_rank=2,
    fed_cfg=FederatedConfig(
        num_devices=4,
        devices_per_round=4,
        rate_grid=(0.1, 0.3, 0.5, 0.7, 0.9),
        num_candidates=3,
        explore_rate=0.34,
        explore_interval=4,
        window_size=6,
    ),
)
cfgor = runner.state.configurator


def sweet_spot(round_idx: int) -> float:
    # early training tolerates aggressive dropout; later rounds need more depth
    return 0.7 if round_idx < 20 else 0.3


for rnd in range(40):
    rates = cfgor.next_round(n_devices=4)
    spot = sweet_spot(rnd)
    gains = [max(0.0, 0.05 - 0.08 * (r - spot) ** 2 + 0.004 * rng.standard_normal()) for r in rates]
    times = [1.0 - 0.5 * r for r in rates]  # higher dropout -> faster rounds
    cfgor.report(rates, gains, times)
    if rnd % 5 == 0:
        phase = "explore" if cfgor.is_explore else "exploit"
        print(f"round {rnd:2d} [{phase:7s}] spot={spot:.1f} best_arm={cfgor.best_rate():.1f} "
              f"used={sorted(set(rates))}")

print("\nfinal best arm:", cfgor.best_rate(), "(sweet spot moved 0.7 -> 0.3)")
