"""End-to-end federated fine-tuning (the paper's full system).

Runs DropPEFT vs FedLoRA on a non-IID synthetic task through the
``repro.api`` facade and prints the time-to-accuracy comparison — a
miniature of paper Table 3.

    PYTHONPATH=src python examples/federated_finetune.py
"""
import numpy as np

from repro import api
from repro.configs import FederatedConfig, TrainConfig

fed = FederatedConfig(num_devices=10, devices_per_round=4, local_steps=4,
                      batch_size=16, dirichlet_alpha=1.0)
ROUNDS = 10

results = {}
for method in ("fedlora", "droppeft"):
    res = api.experiment(
        method,
        model="qwen3-1.7b",
        model_overrides=dict(num_layers=4, d_model=64, d_ff=128, num_heads=4,
                             num_kv_heads=2, vocab_size=512, dtype="float32"),
        peft="lora",
        lora_rank=4,
        fed_cfg=fed,
        train_cfg=TrainConfig(learning_rate=5e-3, total_steps=400, warmup_steps=5),
        cost_model="qwen3-1.7b",  # time accounting at 1.7B scale
        seed=0,
        rounds=ROUNDS,
    )
    results[method] = res
    print(f"\n== {method} ==")
    for r in range(res.rounds):
        print(f"  round {r}: acc={res.accuracy[r]:.3f} "
              f"active={res.active_fraction[r]:.2f} t={res.cum_time_s[r]/3600:.2f}h")
    print(f"  final acc={res.final_accuracy:.3f} "
          f"total sim-time={res.cum_time_s[-1]/3600:.2f}h "
          f"traffic={np.sum(res.traffic_mb):.0f}MB")

target = min(r.accuracy.max() for r in results.values()) * 0.95
t_base = results["fedlora"].time_to_accuracy(target)
t_drop = results["droppeft"].time_to_accuracy(target)
if t_base and t_drop:
    print(f"\nDropPEFT speedup to acc {target:.2f}: {t_base / t_drop:.2f}x (paper: 1.3-6.3x)")
