"""Quickstart: the DropPEFT core in ~60 lines.

Builds a small qwen3-family model, attaches LoRA, and runs a few STLD
training steps — the paper's Eq. 3 layer gating end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import FederatedConfig, PEFTConfig, TrainConfig, get_config
from repro.core import peft as peft_lib
from repro.core import stld
from repro.core.schedules import drop_rates
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import adamw_init

key = jax.random.PRNGKey(0)
cfg = get_config("qwen3-1.7b", smoke=True).replace(dtype="float32")
print(f"model: {cfg.name}  L={cfg.num_layers} d={cfg.d_model}")

# 1. per-layer dropout rates — the paper recommends the incremental shape
rates = drop_rates("incremental", 0.5, cfg.num_layers)
print("dropout rates:", [round(float(r), 2) for r in rates])
print("expected active layers:", float(stld.expected_active_layers(rates)))

# 2. frozen base + trainable LoRA
base = init_params(key, cfg)
peft_cfg = PEFTConfig(method="lora", lora_rank=4)
peft = peft_lib.init_peft(jax.random.fold_in(key, 1), cfg, peft_cfg)
print(f"base params: {peft_lib.count_params(base):,}   "
      f"trainable (LoRA): {peft_lib.count_params(peft):,}")

# 3. STLD training steps (paper-faithful cond mode)
step = jax.jit(
    make_train_step(
        cfg, peft_cfg, TrainConfig(learning_rate=1e-3),
        stld_mode="cond", mean_rate=0.5,
    )
)
opt = adamw_init(peft)
for i in range(5):
    batch = {
        "tokens": jax.random.randint(jax.random.fold_in(key, 10 + i), (4, 33), 0, cfg.vocab_size)
    }
    peft, opt, metrics = step(base, peft, opt, batch, jax.random.fold_in(key, 100 + i))
    print(f"step {i}: loss={float(metrics['loss']):.3f} grad_norm={float(metrics['grad_norm']):.3f}")

# 4. the full federated system is one facade call away
from repro import api

res = api.experiment(
    "droppeft",
    model_overrides=dict(num_layers=4, d_model=32, d_ff=64, num_heads=2,
                         num_kv_heads=2, vocab_size=128, dtype="float32"),
    lora_rank=2,
    fed_cfg=FederatedConfig(num_devices=4, devices_per_round=2, local_steps=2, batch_size=8),
    train_cfg=TrainConfig(learning_rate=5e-3, total_steps=100, warmup_steps=2),
    rounds=2,
)
print(f"federated (repro.api): 2 rounds, acc={res.accuracy[-1]:.3f}")
print("OK — see examples/federated_finetune.py for the full federated system")
