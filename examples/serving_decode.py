"""Serving examples: (1) multi-tenant adapter serving — two federated
clients' LoRA adapters answering interleaved requests through ONE compiled
decode step; (2) LoRA-merged single-tenant deployment; (3) the
sequence-sharded LSE-combined attention math used for long_500k decode.

    PYTHONPATH=src python examples/serving_decode.py
"""
import jax
import jax.numpy as jnp

from repro import api
from repro.configs import PEFTConfig, get_config
from repro.core import peft as peft_lib
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_params
from repro.models.transformer import init_caches
from repro.serving import Request
from repro.serving.decode import _partial_attention, generate

key = jax.random.PRNGKey(0)

# --- multi-tenant: two clients' adapters, one decode batch ---------------
# In a real deployment the adapters come out of a federated run's
# checkpoint: api.serve(checkpoint_dir="ckpts") registers every client's
# adapter as "client<id>". Here we build two hetlora clients in-process
# (different ranks — they still share one pooled kernel).
cfg = get_config("qwen3-1.7b", smoke=True).replace(num_layers=2, dtype="float32")
adapters = {}
for i, rank in enumerate((4, 8)):
    pcfg = PEFTConfig(method="lora", lora_rank=rank, lora_targets=("q", "v"))
    tree = peft_lib.init_peft(jax.random.fold_in(key, i), cfg, pcfg)
    adapters[f"client{i}"] = jax.tree.map(  # LoRA init keeps b=0; perturb
        lambda x: x + 0.02 * jax.random.normal(jax.random.fold_in(key, 9), x.shape),
        tree,
    )

batcher = api.serve(cfg=cfg, adapters=adapters, batch=3, max_len=32,
                    cache_dtype="float32")
requests = [
    Request(prompt=[5, 7, 11], adapter="client0", max_new_tokens=6, uid="a"),
    Request(prompt=[13, 17], adapter="client1", max_new_tokens=6, uid="b"),
    Request(prompt=[19, 23, 29], adapter="client0", max_new_tokens=4, uid="c"),
]
for r in requests:
    batcher.submit(r)
for c in sorted(batcher.run(), key=lambda c: c.uid):
    print(f"req {c.uid} [{c.adapter}] {c.finish_reason}: {c.tokens}")
print(f"pool: {batcher.pool.n_slots} slots, {batcher.pool.swaps} swaps")

# --- single-tenant deployment: fold one LoRA into the base weights -------
cfg = get_config("h2o-danube-1.8b", smoke=True).replace(dtype="float32", sliding_window=32)
params = init_params(key, cfg)
peft_cfg = PEFTConfig(method="lora", lora_rank=4)
lora = peft_lib.init_peft(jax.random.fold_in(key, 1), cfg, peft_cfg)
params = dict(params, layers=peft_lib.merge_lora_into_base(
    params["layers"], lora, peft_lib.lora_scale(peft_cfg)))

prefill = jax.jit(make_prefill_step(cfg))
serve = jax.jit(make_serve_step(cfg))

B, PROMPT, GEN = 2, 24, 12
prompt = jax.random.randint(key, (B, PROMPT), 0, cfg.vocab_size)
caches = init_caches(cfg, B, PROMPT + GEN, dtype=jnp.float32)
last, caches = prefill(params, {"tokens": prompt}, caches)
first = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
tokens, _ = generate(serve, params, caches, first, PROMPT, GEN)
print("generated:", tokens[0].tolist())

# --- long-context decode math: shard the KV cache, combine with LSE ------
h, d, S = 4, 16, 64
q = jax.random.normal(key, (1, h, d))
k = jax.random.normal(jax.random.fold_in(key, 2), (1, S, h, d))
v = jax.random.normal(jax.random.fold_in(key, 3), (1, S, h, d))
kpos = jnp.arange(S)

acc, m, l = _partial_attention(q, k, v, kpos, S - 1, None)
mono = acc / l[..., None]

parts = [
    _partial_attention(q, k[:, i * 16:(i + 1) * 16], v[:, i * 16:(i + 1) * 16],
                       kpos[i * 16:(i + 1) * 16], S - 1, None)
    for i in range(4)  # 4 "devices", each holding a 16-token cache shard
]
m_glob = jnp.max(jnp.stack([p[1] for p in parts]), axis=0)
l_glob = sum(p[2] * jnp.exp(p[1] - m_glob) for p in parts)
acc_glob = sum(p[0] * jnp.exp(p[1] - m_glob)[..., None] for p in parts)
sharded = acc_glob / l_glob[..., None]
print("sharded-decode max err vs monolithic:",
      float(jnp.max(jnp.abs(sharded - mono))))
